//! Regeneration harness for every table and figure of the evaluation (§3).
//!
//! Each function sweeps the paper's parameter range, runs the deterministic
//! scenario drivers, and returns the series as rows; `print_*` renders the
//! paper-shaped table and [`crate::metrics::Series`] handles TSV. The
//! criterion-style benches in `rust/benches/` call the same functions, so
//! `cargo bench`, `rdmavisor fig --id N` (JSON output) and
//! `rdmavisor figures --all` all produce identical numbers.
//!
//! Every sweep function takes a `jobs` count (the CLI's `--jobs N`):
//! each independent sweep point runs its own `Sim` on its own thread via
//! [`crate::util::parallel::map_indexed`] and the rows are merged in
//! index order, so the serialized output of `--jobs N` is byte-for-byte
//! the output of the serial runner (`--jobs 1`, the exact old code
//! path) — `tests/determinism.rs` gates this.

use crate::fabric::sim::FabricConfig;
use crate::fabric::time::Ns;
use crate::fabric::types::{QpTransport, Verb};
use crate::fabric::verbs::capability_matrix;
use crate::metrics::Series;
use crate::util::parallel;
use crate::fabric::topo::CcMode;
use crate::workload::scenarios::{
    chaos_send, churn_storm, failover_storm, incast_storm, kv_storm, locked_random_read,
    naive_random_read, raas_random_read, scale_send, verbs_sweep_point, ChaosCfg, ChaosRun,
    ChurnCfg, ChurnRun, FailoverCfg, FailoverRun, IncastCfg, IncastRun, KvCfg, KvRun, RunStats,
    ScaleCfg, ScaleRun, ScenarioCfg, FAILOVER_BIN_NS,
};

/// Message sizes swept in Fig 1 (64 B … 1 MB).
pub const FIG1_SIZES: &[u64] = &[
    64,
    256,
    1 << 10,
    4 << 10,
    16 << 10,
    64 << 10,
    256 << 10,
    1 << 20,
];

/// Connection counts swept in Fig 5 (up to 1000, knee at ~400).
pub const FIG5_CONNS: &[usize] = &[50, 100, 200, 300, 400, 500, 600, 700, 800, 1000];

/// Thread counts swept in Fig 6.
pub const FIG6_THREADS: &[usize] = &[6, 12, 18, 24, 36, 48];

/// Application counts swept in Figs 7/8.
pub const FIG78_APPS: &[u32] = &[1, 2, 4, 8, 16, 32];

/// Short-run mode for tests/CI; full mode for the recorded experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Budget {
    /// Shrunken sweeps for tests/CI (`--quick` / RDMAVISOR_BENCH_QUICK).
    Quick,
    /// The paper-scale sweeps.
    Full,
}

impl Budget {
    /// Quick iff `RDMAVISOR_BENCH_QUICK` is set.
    pub fn from_env() -> Budget {
        if std::env::var("RDMAVISOR_BENCH_QUICK").is_ok() {
            Budget::Quick
        } else {
            Budget::Full
        }
    }

    fn duration(self) -> Ns {
        match self {
            Budget::Quick => Ns::from_ms(4),
            Budget::Full => Ns::from_ms(20),
        }
    }
}

// ------------------------------------------------------------------ Table 1

/// Print the Table-1 capability matrix as enforced by the fabric.
pub fn table1() -> String {
    let mut out = String::new();
    out.push_str("Table 1: operations & max message size per transport\n");
    out.push_str(&format!(
        "{:<6} {:>10} {:>7} {:>6} {:>12}\n",
        "", "SEND/RECV", "WRITE", "READ", "Max Message"
    ));
    for row in capability_matrix(4096) {
        let fmt_b = |b: bool| if b { "yes" } else { "-" };
        let max = if row.max_msg == 1 << 30 {
            "1GB".to_string()
        } else {
            format!("{} (MTU)", row.max_msg)
        };
        out.push_str(&format!(
            "{:<6} {:>10} {:>7} {:>6} {:>12}\n",
            row.transport.to_string(),
            fmt_b(row.send_recv),
            fmt_b(row.write),
            fmt_b(row.read),
            max
        ));
    }
    out
}

// ------------------------------------------------------------------- Fig 1

/// One Fig-1 series point: (size, Gb/s).
#[derive(Clone, Copy, Debug)]
pub struct Fig1Row {
    /// Message size of this sweep point.
    pub msg_bytes: u64,
    /// RC READ throughput, Gb/s.
    pub rc_read: f64,
    /// RC WRITE throughput, Gb/s.
    pub rc_write: f64,
    /// UC WRITE throughput, Gb/s.
    pub uc_write: f64,
    /// NaN above MTU (UD cannot carry it — Table 1).
    pub ud_send: f64,
}

/// Fig 1: single-QP-pair throughput vs message size, per (transport,
/// verb), one size point per worker at `jobs > 1`.
pub fn fig1(budget: Budget, jobs: usize) -> Vec<Fig1Row> {
    let d = budget.duration();
    let window = 16;
    parallel::map_indexed(FIG1_SIZES.to_vec(), jobs, |_, sz| Fig1Row {
        msg_bytes: sz,
        rc_read: verbs_sweep_point(QpTransport::Rc, Verb::Read, sz, window, d),
        rc_write: verbs_sweep_point(QpTransport::Rc, Verb::Write, sz, window, d),
        uc_write: verbs_sweep_point(QpTransport::Uc, Verb::Write, sz, window, d),
        ud_send: if sz <= 4096 {
            verbs_sweep_point(QpTransport::Ud, Verb::Send, sz, window, d)
        } else {
            f64::NAN
        },
    })
}

/// Render the Fig-1 table.
pub fn print_fig1(rows: &[Fig1Row]) -> String {
    let mut out = String::new();
    out.push_str("Fig 1: throughput (Gb/s) vs message size, single QP pair, window 16\n");
    out.push_str(&format!(
        "{:>10} {:>9} {:>9} {:>9} {:>9}\n",
        "size", "RC READ", "RC WRITE", "UC WRITE", "UD SEND"
    ));
    for r in rows {
        let ud = if r.ud_send.is_nan() { "n/a".into() } else { format!("{:.2}", r.ud_send) };
        out.push_str(&format!(
            "{:>10} {:>9.2} {:>9.2} {:>9.2} {:>9}\n",
            human_size(r.msg_bytes),
            r.rc_read,
            r.rc_write,
            r.uc_write,
            ud
        ));
    }
    out
}

// ------------------------------------------------------------------- Fig 5

/// One Fig-5 sweep point: naive vs RaaS at one connection count.
#[derive(Clone, Copy, Debug)]
pub struct Fig5Row {
    /// Connection count of this sweep point.
    pub conns: usize,
    /// One-QP-per-connection baseline stats.
    pub naive: RunStats,
    /// RDMAvisor shared-QP stats.
    pub raas: RunStats,
}

/// Fig 5: scalability — random 64 KB READ throughput vs #connections.
pub fn fig5(budget: Budget, jobs: usize) -> Vec<Fig5Row> {
    let conns: Vec<usize> = match budget {
        Budget::Quick => vec![50, 200, 400, 600, 800],
        Budget::Full => FIG5_CONNS.to_vec(),
    };
    parallel::map_indexed(conns, jobs, |_, c| {
        let mut cfg = ScenarioCfg::default();
        cfg.conns = c;
        // fig 5 always runs a long window: with hundreds of outstanding
        // 64 KB reads one closed-loop round takes ~10 ms, and the
        // ICM-thrash regime develops only after reposts become
        // engine-gated
        cfg.duration = Ns::from_ms(40);
        cfg.warmup_frac = 0.4;
        Fig5Row { conns: c, naive: naive_random_read(&cfg), raas: raas_random_read(&cfg) }
    })
}

/// Render the Fig-5 table.
pub fn print_fig5(rows: &[Fig5Row]) -> String {
    let mut out = String::new();
    out.push_str("Fig 5: scalability — random 64 KB READ, throughput (Gb/s) vs #connections\n");
    out.push_str(&format!(
        "{:>7} {:>11} {:>11} {:>12} {:>12}\n",
        "conns", "naive Gb/s", "RaaS Gb/s", "naive cache", "RaaS cache"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>7} {:>11.2} {:>11.2} {:>11.1}% {:>11.1}%\n",
            r.conns,
            r.naive.gbps,
            r.raas.gbps,
            r.naive.cache_hit_rate * 100.0,
            r.raas.cache_hit_rate * 100.0
        ));
    }
    out
}

// ------------------------------------------------------------------- Fig 6

/// One Fig-6 sweep point: lock-free vs locked sharing at one thread count.
#[derive(Clone, Copy, Debug)]
pub struct Fig6Row {
    /// Worker threads of this sweep point.
    pub threads: usize,
    /// RDMAvisor lock-free sharing stats.
    pub raas: RunStats,
    /// FaRM-style locked sharing, 3 threads per QP.
    pub locked_q3: RunStats,
    /// FaRM-style locked sharing, 6 threads per QP.
    pub locked_q6: RunStats,
}

/// Fig 6 uses small (512 B) random reads so per-op costs (and therefore
/// lock serialization) dominate; the paper does not state the size — this
/// assumption is recorded in EXPERIMENTS.md.
pub fn fig6(budget: Budget, jobs: usize) -> Vec<Fig6Row> {
    let threads: Vec<usize> = match budget {
        Budget::Quick => vec![6, 12, 24],
        Budget::Full => FIG6_THREADS.to_vec(),
    };
    parallel::map_indexed(threads, jobs, |_, t| {
        let mut cfg = ScenarioCfg::default();
        cfg.conns = t;
        cfg.msg_bytes = 512;
        cfg.window = 4;
        cfg.duration = budget.duration();
        Fig6Row {
            threads: t,
            raas: raas_random_read(&cfg),
            locked_q3: locked_random_read(&cfg, 3),
            locked_q6: locked_random_read(&cfg, 6),
        }
    })
}

/// Render the Fig-6 table.
pub fn print_fig6(rows: &[Fig6Row]) -> String {
    let mut out = String::new();
    out.push_str("Fig 6: QP sharing — random 512 B READ, Mops vs worker threads\n");
    out.push_str(&format!(
        "{:>8} {:>10} {:>12} {:>12} {:>14}\n",
        "threads", "RaaS Mops", "lock q=3", "lock q=6", "q6 lock-wait"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>8} {:>10.3} {:>12.3} {:>12.3} {:>12.2}ms\n",
            r.threads, r.raas.mops, r.locked_q3.mops, r.locked_q6.mops, r.locked_q6.lock_wait_ms
        ));
    }
    out
}

// --------------------------------------------------------------- Figs 7/8

/// One Figs-7/8 sweep point: normalized resources at one app count.
#[derive(Clone, Copy, Debug)]
pub struct Fig78Row {
    /// Applications of this sweep point.
    pub apps: u32,
    /// Naive memory, in units of one naive app.
    pub naive_mem: f64,
    /// RaaS memory, in units of one naive app.
    pub raas_mem: f64,
    /// Naive CPU, in units of one naive app.
    pub naive_cpu: f64,
    /// RaaS CPU, in units of one naive app.
    pub raas_cpu: f64,
}

/// Figs 7 & 8: normalized memory/CPU vs number of applications. One unit =
/// the resources one naive application consumes (the paper's normalization).
pub fn fig78(budget: Budget, jobs: usize) -> Vec<Fig78Row> {
    let conns_per_app = 16;
    let run = |apps: u32| -> (RunStats, RunStats) {
        let mut cfg = ScenarioCfg::default();
        cfg.apps = apps;
        cfg.conns = (apps * conns_per_app) as usize;
        cfg.duration = budget.duration();
        (naive_random_read(&cfg), raas_random_read(&cfg))
    };
    // normalization unit: one naive app
    let (n1, _) = run(1);
    let unit_mem = n1.mem_bytes.max(1) as f64;
    let unit_cpu = n1.cpu_cores.max(1e-9);

    let apps: Vec<u32> = match budget {
        Budget::Quick => vec![1, 4, 16],
        Budget::Full => FIG78_APPS.to_vec(),
    };
    parallel::map_indexed(apps, jobs, |_, a| {
        let (n, r) = run(a);
        Fig78Row {
            apps: a,
            naive_mem: n.mem_bytes as f64 / unit_mem,
            raas_mem: r.mem_bytes as f64 / unit_mem,
            naive_cpu: n.cpu_cores / unit_cpu,
            raas_cpu: r.cpu_cores / unit_cpu,
        }
    })
}

/// Render the Fig-7 (memory) table.
pub fn print_fig7(rows: &[Fig78Row]) -> String {
    let mut out = String::new();
    out.push_str("Fig 7: normalized memory usage vs #applications (unit = 1 naive app)\n");
    out.push_str(&format!("{:>6} {:>12} {:>12}\n", "apps", "naive", "RaaS"));
    for r in rows {
        out.push_str(&format!("{:>6} {:>12.2} {:>12.2}\n", r.apps, r.naive_mem, r.raas_mem));
    }
    out
}

/// Render the Fig-8 (CPU) table.
pub fn print_fig8(rows: &[Fig78Row]) -> String {
    let mut out = String::new();
    out.push_str("Fig 8: normalized CPU consumption vs #applications (unit = 1 naive app)\n");
    out.push_str(&format!("{:>6} {:>12} {:>12}\n", "apps", "naive", "RaaS"));
    for r in rows {
        out.push_str(&format!("{:>6} {:>12.2} {:>12.2}\n", r.apps, r.naive_cpu, r.raas_cpu));
    }
    out
}

// ------------------------------------------------------------------- Fig 9

/// Connection counts swept in the Fig-9 scale experiment (2 → 32768; the
/// destination fan-out caps at [`FIG9_MAX_SERVERS`], so the ICM knee sits
/// where destinations pass the cache's RC budget). The 16k/32k points
/// became affordable with the timing-wheel/dense-state event loop (PR 3).
pub const FIG9_CONNS: &[usize] =
    &[2, 64, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768];

/// Destination-daemon cap of the Fig-9 sweep.
pub const FIG9_MAX_SERVERS: usize = 1024;

/// One Fig-9 sweep point: adaptive migration vs the RC-only ablation.
#[derive(Clone, Copy, Debug)]
pub struct Fig9Row {
    /// Connection count of this sweep point.
    pub conns: usize,
    /// Adaptive RC↔UD migration run (None in the `--rc-only` ablation).
    pub adaptive: Option<ScaleRun>,
    /// RC-only ablation run.
    pub rc_only: ScaleRun,
}

/// The Fig-9 [`ScaleCfg`] for one sweep point (shared with the `bench
/// fig9` wall-clock benchmark so BENCH_PR3.json times exactly the runs
/// the figure makes).
pub fn fig9_cfg(conns: usize, budget: Budget, rc_only: bool) -> ScaleCfg {
    let mut cfg = ScaleCfg::default();
    cfg.conns = conns;
    cfg.max_servers = FIG9_MAX_SERVERS;
    cfg.rc_only = rc_only;
    cfg.duration = match budget {
        Budget::Quick => Ns::from_ms(4),
        Budget::Full => Ns::from_ms(10),
    };
    cfg
}

/// The Fig-9 connection counts for a budget (shared with `bench fig9`).
pub fn fig9_conns(budget: Budget) -> Vec<usize> {
    match budget {
        Budget::Quick => vec![2, 256, 2048],
        Budget::Full => FIG9_CONNS.to_vec(),
    }
}

/// Fig 9: thousand-connection scale — adaptive RC↔UD migration vs the
/// RC-only ablation, 64 B–4 KB closed-loop `send()` traffic. Each
/// (connection count, ablation) pair is its own independent `Sim`, so
/// the parallel runner schedules them as separate work items.
pub fn fig9(budget: Budget, jobs: usize) -> Vec<Fig9Row> {
    fig9_sharded(budget, jobs, 1)
}

/// [`fig9`] with each point's `Sim` split into `shards` partitions
/// (conservative parallel execution; output bytes are shard-invariant,
/// gated by `tests/determinism.rs`).
pub fn fig9_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig9Row> {
    let conns = fig9_conns(budget);
    let mut items = Vec::with_capacity(conns.len() * 2);
    for &c in &conns {
        items.push((c, false));
        items.push((c, true));
    }
    let runs = parallel::map_indexed(items, jobs, |_, (c, rc_only)| {
        let mut cfg = fig9_cfg(c, budget, rc_only);
        cfg.shards = shards;
        scale_send(&cfg)
    });
    conns
        .into_iter()
        .enumerate()
        .map(|(i, c)| Fig9Row { conns: c, adaptive: Some(runs[2 * i]), rc_only: runs[2 * i + 1] })
        .collect()
}

/// The `--rc-only` ablation alone (adaptive column omitted).
pub fn fig9_rc_only(budget: Budget, jobs: usize) -> Vec<Fig9Row> {
    fig9_rc_only_sharded(budget, jobs, 1)
}

/// [`fig9_rc_only`] with a sharded `Sim` per point (shard-invariant).
pub fn fig9_rc_only_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig9Row> {
    parallel::map_indexed(fig9_conns(budget), jobs, |_, c| {
        let mut cfg = fig9_cfg(c, budget, true);
        cfg.shards = shards;
        Fig9Row { conns: c, adaptive: None, rc_only: scale_send(&cfg) }
    })
}

/// Render the Fig-9 table.
pub fn print_fig9(rows: &[Fig9Row]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 9: scale — adaptive RC\u{2194}UD migration vs RC-only, 64B-4KB sends\n",
    );
    out.push_str(&format!(
        "{:>7} {:>8} {:>10} {:>11} {:>8} {:>10} {:>11} {:>10}\n",
        "conns", "servers", "adpt Gb/s", "rc-only G/s", "UD frac", "adpt hit", "rc-only hit", "migrations"
    ));
    for r in rows {
        let (ag, af, ah, am) = match &r.adaptive {
            Some(a) => (
                format!("{:.2}", a.gbps),
                format!("{:.2}", a.ud_fraction),
                format!("{:.1}%", a.cache_hit_rate * 100.0),
                format!("{}", a.migrations_to_ud),
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        out.push_str(&format!(
            "{:>7} {:>8} {:>10} {:>11.2} {:>8} {:>10} {:>10.1}% {:>10}\n",
            r.conns,
            r.rc_only.servers,
            ag,
            r.rc_only.gbps,
            af,
            ah,
            r.rc_only.cache_hit_rate * 100.0,
            am
        ));
    }
    out
}

/// The Fig-9 [`Series`] (shared by the CLI and the determinism tests).
pub fn fig9_series(rows: &[Fig9Row]) -> Series {
    let mut s = Series::new(
        "fig9_scale",
        "conns",
        &[
            "adaptive_gbps",
            "rc_only_gbps",
            "adaptive_mops",
            "rc_only_mops",
            "ud_fraction",
            "adaptive_cache",
            "rc_only_cache",
            "adaptive_cpu",
            "rc_only_cpu",
            "adaptive_mem_bytes",
            "rc_only_mem_bytes",
        ],
    );
    for r in rows {
        let a = r.adaptive;
        let pick = |f: fn(&ScaleRun) -> f64| a.as_ref().map(f).unwrap_or(f64::NAN);
        s.push(
            r.conns as f64,
            vec![
                pick(|x| x.gbps),
                r.rc_only.gbps,
                pick(|x| x.mops),
                r.rc_only.mops,
                pick(|x| x.ud_fraction),
                pick(|x| x.cache_hit_rate),
                r.rc_only.cache_hit_rate,
                pick(|x| x.cpu_cores),
                r.rc_only.cpu_cores,
                pick(|x| x.fabric_mem_bytes as f64),
                r.rc_only.fabric_mem_bytes as f64,
            ],
        );
    }
    s
}

// ------------------------------------------------------------------ Fig 10

/// Loss rates swept in the fig-10 chaos experiment (fraction of frames
/// dropped iid; burst episodes and link flaps ride along at loss > 0).
pub const FIG10_LOSS: &[f64] = &[0.0, 0.001, 0.005, 0.02, 0.05];

/// The fig-10 loss rates for a budget (shared with the determinism gate).
pub fn fig10_loss_rates(budget: Budget) -> Vec<f64> {
    match budget {
        Budget::Quick => vec![0.0, 0.01, 0.05],
        Budget::Full => FIG10_LOSS.to_vec(),
    }
}

/// The fig-10 [`ChaosCfg`] for one sweep point. Loss 0 carries no flaps
/// either, so its plan is null and the run is byte-identical to the
/// lossless simulator; lossy points add link flaps long enough to
/// exhaust the RC retry budget.
pub fn fig10_cfg(loss: f64, budget: Budget, rc_only: bool) -> ChaosCfg {
    let mut cfg = ChaosCfg::default();
    cfg.loss = loss;
    cfg.rc_only = rc_only;
    cfg.conns = match budget {
        Budget::Quick => 96,
        Budget::Full => 192,
    };
    cfg.duration = match budget {
        Budget::Quick => Ns::from_ms(4),
        Budget::Full => Ns::from_ms(12),
    };
    cfg.flaps = if loss > 0.0 {
        match budget {
            Budget::Quick => 3,
            Budget::Full => 6,
        }
    } else {
        0
    };
    cfg
}

/// One fig-10 sweep point: adaptive migration vs the RC-only ablation at
/// one injected loss rate.
#[derive(Clone, Copy, Debug)]
pub struct Fig10Row {
    /// Injected per-frame loss rate.
    pub loss: f64,
    /// Adaptive RC↔UD run (None in the `--rc-only` ablation).
    pub adaptive: Option<ChaosRun>,
    /// RC-only ablation run.
    pub rc_only: ChaosRun,
}

/// Fig 10: goodput + p99 vs injected loss rate, adaptive vs RC-only.
/// RC pays for loss with retransmissions and (inside flap windows) retry
/// exhaustion; UD pays with silently discarded fragmented messages.
pub fn fig10(budget: Budget, jobs: usize) -> Vec<Fig10Row> {
    fig10_sharded(budget, jobs, 1)
}

/// [`fig10`] with a sharded `Sim` per point (shard-invariant output).
pub fn fig10_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig10Row> {
    let losses = fig10_loss_rates(budget);
    let mut items = Vec::with_capacity(losses.len() * 2);
    for &loss in &losses {
        items.push((loss, false));
        items.push((loss, true));
    }
    let runs = parallel::map_indexed(items, jobs, |_, (loss, rc_only)| {
        let mut cfg = fig10_cfg(loss, budget, rc_only);
        cfg.shards = shards;
        chaos_send(&cfg)
    });
    losses
        .into_iter()
        .enumerate()
        .map(|(i, loss)| Fig10Row {
            loss,
            adaptive: Some(runs[2 * i]),
            rc_only: runs[2 * i + 1],
        })
        .collect()
}

/// The `--rc-only` ablation alone (adaptive column omitted).
pub fn fig10_rc_only(budget: Budget, jobs: usize) -> Vec<Fig10Row> {
    fig10_rc_only_sharded(budget, jobs, 1)
}

/// [`fig10_rc_only`] with a sharded `Sim` per point (shard-invariant).
pub fn fig10_rc_only_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig10Row> {
    parallel::map_indexed(fig10_loss_rates(budget), jobs, |_, loss| {
        let mut cfg = fig10_cfg(loss, budget, true);
        cfg.shards = shards;
        Fig10Row { loss, adaptive: None, rc_only: chaos_send(&cfg) }
    })
}

/// Render the Fig-10 table.
pub fn print_fig10(rows: &[Fig10Row]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 10: chaos — goodput/p99 vs injected loss, adaptive RC\u{2194}UD vs RC-only\n",
    );
    out.push_str(&format!(
        "{:>7} {:>10} {:>11} {:>9} {:>9} {:>8} {:>8} {:>9} {:>9}\n",
        "loss", "adpt Gb/s", "rc-only G/s", "adpt p99", "rc p99", "retrans", "rexceed", "ud drops", "reclaimed"
    ));
    for r in rows {
        let (ag, ap, ud, rec) = match &r.adaptive {
            Some(a) => (
                format!("{:.2}", a.gbps),
                format!("{:.1}", a.p99_us),
                format!("{}", a.ud_dropped + a.ud_orphans + a.ud_expired),
                format!("{}", a.leases_reclaimed + r.rc_only.leases_reclaimed),
            ),
            None => ("-".into(), "-".into(), "-".into(), format!("{}", r.rc_only.leases_reclaimed)),
        };
        let retrans = r.rc_only.retransmits + r.adaptive.map(|a| a.retransmits).unwrap_or(0);
        let rexceed = r.rc_only.retry_exceeded + r.adaptive.map(|a| a.retry_exceeded).unwrap_or(0);
        out.push_str(&format!(
            "{:>6.3}% {:>10} {:>11.2} {:>9} {:>8.1} {:>8} {:>8} {:>9} {:>9}\n",
            r.loss * 100.0,
            ag,
            r.rc_only.gbps,
            ap,
            r.rc_only.p99_us,
            retrans,
            rexceed,
            ud,
            rec
        ));
    }
    out
}

/// The Fig-10 [`Series`] (shared by the CLI and the determinism tests).
pub fn fig10_series(rows: &[Fig10Row]) -> Series {
    let mut s = Series::new(
        "fig10_chaos",
        "loss",
        &[
            "adaptive_gbps",
            "rc_only_gbps",
            "adaptive_p99_us",
            "rc_only_p99_us",
            "adaptive_mops",
            "rc_only_mops",
            "ud_fraction",
            "adaptive_failed_ops",
            "rc_only_failed_ops",
            "retransmits",
            "retry_exceeded",
            "ud_reassembly_discards",
            "frames_dropped",
            "leases_reclaimed",
        ],
    );
    for r in rows {
        let a = r.adaptive;
        let pick = |f: fn(&ChaosRun) -> f64| a.as_ref().map(f).unwrap_or(f64::NAN);
        s.push(
            r.loss,
            vec![
                pick(|x| x.gbps),
                r.rc_only.gbps,
                pick(|x| x.p99_us),
                r.rc_only.p99_us,
                pick(|x| x.mops),
                r.rc_only.mops,
                pick(|x| x.ud_fraction),
                pick(|x| x.failed_ops as f64),
                r.rc_only.failed_ops as f64,
                (r.rc_only.retransmits + a.map(|x| x.retransmits).unwrap_or(0)) as f64,
                (r.rc_only.retry_exceeded + a.map(|x| x.retry_exceeded).unwrap_or(0)) as f64,
                pick(|x| (x.ud_dropped + x.ud_orphans + x.ud_expired) as f64),
                (r.rc_only.frames_dropped + a.map(|x| x.frames_dropped).unwrap_or(0)) as f64,
                (r.rc_only.leases_reclaimed + a.map(|x| x.leases_reclaimed).unwrap_or(0)) as f64,
            ],
        );
    }
    s
}

// ------------------------------------------------------------------ Fig 11

/// Client counts swept in the fig-11 KV experiment.
pub const FIG11_CLIENTS: &[usize] = &[64, 256, 1024, 4096];

/// The fig-11 client counts for a budget (shared with `bench kv`).
pub fn fig11_clients(budget: Budget) -> Vec<usize> {
    match budget {
        Budget::Quick => vec![64, 1024],
        Budget::Full => FIG11_CLIENTS.to_vec(),
    }
}

/// The fig-11 [`KvCfg`] for one sweep point (shared with `bench kv` so
/// BENCH_PR6.json times exactly the runs the figure makes).
/// `write_heavy` flips the mix from read-mostly 95/5 to 50/50.
pub fn fig11_cfg(clients: usize, budget: Budget, rpc: bool, write_heavy: bool) -> KvCfg {
    let mut cfg = KvCfg::default();
    cfg.clients = clients;
    cfg.rpc = rpc;
    cfg.read_pct = if write_heavy { 50 } else { 95 };
    cfg.duration = match budget {
        Budget::Quick => Ns::from_ms(4),
        Budget::Full => Ns::from_ms(10),
    };
    cfg
}

/// One fig-11 sweep point: one-sided window GET/PUT vs the SEND-RPC
/// ablation, at both workload mixes.
#[derive(Clone, Copy, Debug)]
pub struct Fig11Row {
    /// Closed-loop client count of this sweep point.
    pub clients: usize,
    /// One-sided, read-mostly 95/5 (None in the `--rc-only` ablation).
    pub os_read: Option<KvRun>,
    /// SEND-RPC, read-mostly 95/5.
    pub rpc_read: KvRun,
    /// One-sided, write-heavy 50/50 (None in the `--rc-only` ablation).
    pub os_write: Option<KvRun>,
    /// SEND-RPC, write-heavy 50/50.
    pub rpc_write: KvRun,
}

/// Fig 11: the Zipfian KV tier — app-level ops/sec and tail latency vs
/// client count, one-sided registered-window READ/WRITE vs the SEND-RPC
/// ablation, at read-mostly (95/5) and write-heavy (50/50) mixes. Each
/// (clients, mode, mix) triple is an independent `Sim` work item.
pub fn fig11(budget: Budget, jobs: usize) -> Vec<Fig11Row> {
    fig11_sharded(budget, jobs, 1)
}

/// [`fig11`] with a sharded `Sim` per point (shard-invariant output).
pub fn fig11_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig11Row> {
    let clients = fig11_clients(budget);
    let mut items = Vec::with_capacity(clients.len() * 4);
    for &c in &clients {
        items.push((c, false, false));
        items.push((c, true, false));
        items.push((c, false, true));
        items.push((c, true, true));
    }
    let runs = parallel::map_indexed(items, jobs, |_, (c, rpc, heavy)| {
        let mut cfg = fig11_cfg(c, budget, rpc, heavy);
        cfg.shards = shards;
        kv_storm(&cfg)
    });
    clients
        .into_iter()
        .enumerate()
        .map(|(i, c)| Fig11Row {
            clients: c,
            os_read: Some(runs[4 * i]),
            rpc_read: runs[4 * i + 1],
            os_write: Some(runs[4 * i + 2]),
            rpc_write: runs[4 * i + 3],
        })
        .collect()
}

/// The SEND-RPC ablation alone (`--rc-only`: one-sided columns omitted —
/// everything rides the two-sided RC path).
pub fn fig11_rpc_only(budget: Budget, jobs: usize) -> Vec<Fig11Row> {
    fig11_rpc_only_sharded(budget, jobs, 1)
}

/// [`fig11_rpc_only`] with a sharded `Sim` per point (shard-invariant).
pub fn fig11_rpc_only_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig11Row> {
    let clients = fig11_clients(budget);
    let mut items = Vec::with_capacity(clients.len() * 2);
    for &c in &clients {
        items.push((c, false));
        items.push((c, true));
    }
    let runs = parallel::map_indexed(items, jobs, |_, (c, heavy)| {
        let mut cfg = fig11_cfg(c, budget, true, heavy);
        cfg.shards = shards;
        kv_storm(&cfg)
    });
    clients
        .into_iter()
        .enumerate()
        .map(|(i, c)| Fig11Row {
            clients: c,
            os_read: None,
            rpc_read: runs[2 * i],
            os_write: None,
            rpc_write: runs[2 * i + 1],
        })
        .collect()
}

/// Render the Fig-11 table.
pub fn print_fig11(rows: &[Fig11Row]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 11: Zipfian KV — one-sided window GET/PUT vs SEND-RPC, 64B-128KB values\n",
    );
    out.push_str(&format!(
        "{:>8} {:>10} {:>10} {:>9} {:>9} {:>10} {:>10} {:>10}\n",
        "clients", "1s Mops", "rpc Mops", "1s p99", "rpc p99", "1s srvCPU", "rpc srvCPU", "coalesced"
    ));
    for r in rows {
        let (om, op, oc, co) = match &r.os_read {
            Some(o) => (
                format!("{:.3}", o.mops),
                format!("{:.1}", o.p99_us),
                format!("{:.3}", o.server_cpu_cores),
                format!("{}", o.writes_coalesced + r.os_write.map(|w| w.writes_coalesced).unwrap_or(0)),
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        out.push_str(&format!(
            "{:>8} {:>10} {:>10.3} {:>9} {:>9.1} {:>10} {:>10.3} {:>10}\n",
            r.clients,
            om,
            r.rpc_read.mops,
            op,
            r.rpc_read.p99_us,
            oc,
            r.rpc_read.server_cpu_cores,
            co
        ));
    }
    out
}

/// The Fig-11 [`Series`] (shared by the CLI and the determinism tests).
pub fn fig11_series(rows: &[Fig11Row]) -> Series {
    let mut s = Series::new(
        "fig11_kv",
        "clients",
        &[
            "onesided_read_mops",
            "rpc_read_mops",
            "onesided_write_mops",
            "rpc_write_mops",
            "onesided_read_p50_us",
            "rpc_read_p50_us",
            "onesided_read_p99_us",
            "rpc_read_p99_us",
            "onesided_write_p99_us",
            "rpc_write_p99_us",
            "onesided_gbps",
            "rpc_gbps",
            "onesided_server_cpu",
            "rpc_server_cpu",
            "writes_coalesced",
            "window_flushes",
        ],
    );
    for r in rows {
        let or = r.os_read;
        let ow = r.os_write;
        let pr = |f: fn(&KvRun) -> f64| or.as_ref().map(f).unwrap_or(f64::NAN);
        let pw = |f: fn(&KvRun) -> f64| ow.as_ref().map(f).unwrap_or(f64::NAN);
        s.push(
            r.clients as f64,
            vec![
                pr(|x| x.mops),
                r.rpc_read.mops,
                pw(|x| x.mops),
                r.rpc_write.mops,
                pr(|x| x.p50_us),
                r.rpc_read.p50_us,
                pr(|x| x.p99_us),
                r.rpc_read.p99_us,
                pw(|x| x.p99_us),
                r.rpc_write.p99_us,
                pr(|x| x.gbps),
                r.rpc_read.gbps,
                pr(|x| x.server_cpu_cores),
                r.rpc_read.server_cpu_cores,
                pw(|x| x.writes_coalesced as f64),
                pw(|x| x.window_flushes as f64),
            ],
        );
    }
    s
}

// ------------------------------------------------------------------ Fig 12

/// Tenant-arrival counts swept in the fig-12 churn experiment — toward
/// the paper's 10^6-connection datacenter regime.
pub const FIG12_CONNS: &[usize] = &[10_000, 100_000, 1_000_000];

/// The fig-12 arrival counts for a budget (shared with `bench churn`).
pub fn fig12_conns(budget: Budget) -> Vec<usize> {
    match budget {
        Budget::Quick => vec![1_000, 5_000, 20_000],
        Budget::Full => FIG12_CONNS.to_vec(),
    }
}

/// The fig-12 [`ChurnCfg`] for one sweep point (shared with `bench
/// churn` so BENCH_PR7.json times exactly the runs the figure makes).
pub fn fig12_cfg(conns: usize, cold: bool) -> ChurnCfg {
    let mut cfg = ChurnCfg::default();
    cfg.conns = conns;
    cfg.cold = cold;
    cfg
}

/// One fig-12 sweep point: the elastic control plane (QP reuse pool +
/// lazy batched leases) vs the `--cold` ablation on the same seeded
/// arrival tape.
#[derive(Clone, Copy, Debug)]
pub struct Fig12Row {
    /// Tenant arrivals of this sweep point.
    pub conns: usize,
    /// Warm mode: pool + lazy leases (None in the `--cold` ablation).
    pub warm: Option<ChurnRun>,
    /// Cold mode: no pool, eager leases.
    pub cold: ChurnRun,
}

/// Fig 12: connection-setup rate, first-byte tail latency and
/// per-registered-vQPN memory vs tenant arrivals. Each (conns, mode)
/// pair is an independent `Sim` work item, interleaved so `--jobs N`
/// merges byte-identically with the serial runner.
pub fn fig12(budget: Budget, jobs: usize) -> Vec<Fig12Row> {
    fig12_sharded(budget, jobs, 1)
}

/// [`fig12`] with a sharded `Sim` per point (shard-invariant output).
pub fn fig12_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig12Row> {
    let conns = fig12_conns(budget);
    let mut items = Vec::with_capacity(conns.len() * 2);
    for &c in &conns {
        items.push((c, false));
        items.push((c, true));
    }
    let runs = parallel::map_indexed(items, jobs, |_, (c, cold)| {
        let mut cfg = fig12_cfg(c, cold);
        cfg.shards = shards;
        churn_storm(&cfg)
    });
    conns
        .into_iter()
        .enumerate()
        .map(|(i, c)| Fig12Row { conns: c, warm: Some(runs[2 * i]), cold: runs[2 * i + 1] })
        .collect()
}

/// The `--cold` ablation alone: every reconnect full-handshakes and all
/// leases establish eagerly at connect (warm columns omitted).
pub fn fig12_cold_only(budget: Budget, jobs: usize) -> Vec<Fig12Row> {
    fig12_cold_only_sharded(budget, jobs, 1)
}

/// [`fig12_cold_only`] with a sharded `Sim` per point (shard-invariant).
pub fn fig12_cold_only_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig12Row> {
    let conns = fig12_conns(budget);
    let runs = parallel::map_indexed(conns.clone(), jobs, |_, c| {
        let mut cfg = fig12_cfg(c, true);
        cfg.shards = shards;
        churn_storm(&cfg)
    });
    conns
        .into_iter()
        .enumerate()
        .map(|(i, c)| Fig12Row { conns: c, warm: None, cold: runs[i] })
        .collect()
}

/// Render the Fig-12 table.
pub fn print_fig12(rows: &[Fig12Row]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 12: tenant churn — setup rate, first-byte p99 and idle-vQPN memory, warm vs cold\n",
    );
    out.push_str(&format!(
        "{:>9} {:>10} {:>10} {:>9} {:>9} {:>9} {:>9} {:>8} {:>8}\n",
        "conns", "warm kcps", "cold kcps", "warm p99", "cold p99", "B/vqpn", "cold B/v", "reused",
        "handshk"
    ));
    for r in rows {
        let (wk, wp, wm, wr, wh) = match &r.warm {
            Some(w) => (
                format!("{:.1}", w.setup_kcps),
                format!("{:.1}", w.p99_ttfb_us),
                format!("{:.0}", w.mem_per_vqpn),
                format!("{}", w.qp_reused),
                format!("{}", w.handshakes_full),
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into(), "-".into()),
        };
        out.push_str(&format!(
            "{:>9} {:>10} {:>10.1} {:>9} {:>9.1} {:>9} {:>9.0} {:>8} {:>8}\n",
            r.conns,
            wk,
            r.cold.setup_kcps,
            wp,
            r.cold.p99_ttfb_us,
            wm,
            r.cold.mem_per_vqpn,
            wr,
            wh
        ));
    }
    out
}

/// The Fig-12 [`Series`] (shared by the CLI and the determinism tests).
pub fn fig12_series(rows: &[Fig12Row]) -> Series {
    let mut s = Series::new(
        "fig12_churn",
        "conns",
        &[
            "warm_setup_kcps",
            "cold_setup_kcps",
            "warm_p50_ttfb_us",
            "cold_p50_ttfb_us",
            "warm_p99_ttfb_us",
            "cold_p99_ttfb_us",
            "warm_mem_per_vqpn",
            "cold_mem_per_vqpn",
            "warm_table_bytes_per_vqpn",
            "cold_table_bytes_per_vqpn",
            "warm_handshakes_full",
            "cold_handshakes_full",
            "qp_reused",
            "qp_parked",
            "qp_evicted",
            "lease_batches",
            "leases_established",
            "deferred_leases",
            "stale_epoch_drops",
        ],
    );
    for r in rows {
        let w = r.warm;
        let p = |f: fn(&ChurnRun) -> f64| w.as_ref().map(f).unwrap_or(f64::NAN);
        s.push(
            r.conns as f64,
            vec![
                p(|x| x.setup_kcps),
                r.cold.setup_kcps,
                p(|x| x.p50_ttfb_us),
                r.cold.p50_ttfb_us,
                p(|x| x.p99_ttfb_us),
                r.cold.p99_ttfb_us,
                p(|x| x.mem_per_vqpn),
                r.cold.mem_per_vqpn,
                p(|x| x.table_bytes_per_vqpn),
                r.cold.table_bytes_per_vqpn,
                p(|x| x.handshakes_full as f64),
                r.cold.handshakes_full as f64,
                p(|x| x.qp_reused as f64),
                p(|x| x.qp_parked as f64),
                p(|x| x.qp_evicted as f64),
                p(|x| x.lease_batches as f64),
                p(|x| x.leases_established as f64),
                p(|x| x.deferred_leases as f64),
                p(|x| x.stale_epoch_drops as f64),
            ],
        );
    }
    s
}

// ------------------------------------------------------------------ Fig 13

/// Oversubscription ratios swept in the fig-13 incast experiment: full
/// bisection down to an 8:1 ToR uplink squeeze.
pub const FIG13_OVERSUBS: &[u32] = &[1, 2, 4, 8];

/// The fig-13 oversubscription ratios for a budget (shared with `bench
/// incast`).
pub fn fig13_oversubs(budget: Budget) -> Vec<u32> {
    match budget {
        Budget::Quick => vec![1, 8],
        Budget::Full => FIG13_OVERSUBS.to_vec(),
    }
}

/// The fig-13 [`IncastCfg`] for one sweep point (shared with `bench
/// incast` so BENCH_PR9.json times exactly the runs the figure makes).
pub fn fig13_cfg(oversub: u32, budget: Budget, mode: CcMode) -> IncastCfg {
    let mut cfg = IncastCfg::default();
    cfg.oversub = oversub;
    cfg.mode = mode;
    if budget == Budget::Quick {
        cfg.writers = 8;
        cfg.elephants = 2;
        cfg.mice = 2;
        cfg.window = 8;
        cfg.duration = Ns::from_ms(2);
    }
    cfg
}

/// One fig-13 sweep point: the same incast tape through each
/// congestion-control mode of the Clos fabric.
#[derive(Clone, Copy, Debug)]
pub struct Fig13Row {
    /// ToR uplink oversubscription ratio of this point.
    pub oversub: u32,
    /// DCQCN (ECN marks → CNP echo → per-QP rate cuts).
    pub dcqcn: Option<IncastRun>,
    /// No congestion control: tail-drops + go-back-N only.
    pub no_cc: Option<IncastRun>,
    /// PFC pause chaining: lossless, head-of-line blocking.
    pub pfc: Option<IncastRun>,
}

/// Fig 13: incast goodput and mouse-FCT tail vs ToR oversubscription,
/// DCQCN vs no-CC vs PFC. Each (oversub, mode) pair is an independent
/// `Sim` work item, interleaved so `--jobs N` merges byte-identically
/// with the serial runner.
pub fn fig13(budget: Budget, jobs: usize) -> Vec<Fig13Row> {
    fig13_sharded(budget, jobs, 1)
}

/// [`fig13`] with a sharded `Sim` per point (shard-invariant output).
pub fn fig13_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig13Row> {
    let oversubs = fig13_oversubs(budget);
    let mut items = Vec::with_capacity(oversubs.len() * 3);
    for &o in &oversubs {
        items.push((o, CcMode::Dcqcn));
        items.push((o, CcMode::NoCc));
        items.push((o, CcMode::Pfc));
    }
    let runs = parallel::map_indexed(items, jobs, |_, (o, mode)| {
        let mut cfg = fig13_cfg(o, budget, mode);
        cfg.shards = shards;
        incast_storm(&cfg)
    });
    oversubs
        .into_iter()
        .enumerate()
        .map(|(i, o)| Fig13Row {
            oversub: o,
            dcqcn: Some(runs[3 * i]),
            no_cc: Some(runs[3 * i + 1]),
            pfc: Some(runs[3 * i + 2]),
        })
        .collect()
}

/// The `--no-cc` ablation alone: tail-drop + go-back-N, no rate control
/// (DCQCN and PFC columns omitted).
pub fn fig13_no_cc(budget: Budget, jobs: usize) -> Vec<Fig13Row> {
    fig13_no_cc_sharded(budget, jobs, 1)
}

/// [`fig13_no_cc`] with a sharded `Sim` per point (shard-invariant).
pub fn fig13_no_cc_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig13Row> {
    let oversubs = fig13_oversubs(budget);
    let runs = parallel::map_indexed(oversubs.clone(), jobs, |_, o| {
        let mut cfg = fig13_cfg(o, budget, CcMode::NoCc);
        cfg.shards = shards;
        incast_storm(&cfg)
    });
    oversubs
        .into_iter()
        .enumerate()
        .map(|(i, o)| Fig13Row { oversub: o, dcqcn: None, no_cc: Some(runs[i]), pfc: None })
        .collect()
}

/// The `--pfc` ablation alone: lossless pause chaining (DCQCN and no-CC
/// columns omitted).
pub fn fig13_pfc(budget: Budget, jobs: usize) -> Vec<Fig13Row> {
    fig13_pfc_sharded(budget, jobs, 1)
}

/// [`fig13_pfc`] with a sharded `Sim` per point (shard-invariant).
pub fn fig13_pfc_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig13Row> {
    let oversubs = fig13_oversubs(budget);
    let runs = parallel::map_indexed(oversubs.clone(), jobs, |_, o| {
        let mut cfg = fig13_cfg(o, budget, CcMode::Pfc);
        cfg.shards = shards;
        incast_storm(&cfg)
    });
    oversubs
        .into_iter()
        .enumerate()
        .map(|(i, o)| Fig13Row { oversub: o, dcqcn: None, no_cc: None, pfc: Some(runs[i]) })
        .collect()
}

/// Render the Fig-13 table.
pub fn print_fig13(rows: &[Fig13Row]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fig 13: Clos incast — goodput and mouse p99 FCT vs ToR oversubscription, by CC mode\n",
    );
    out.push_str(&format!(
        "{:>7} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
        "oversub", "dcqcn Gb", "nocc Gb", "pfc Gb", "dcqcn p99", "nocc p99", "pfc p99", "marks",
        "drops", "pauses"
    ));
    for r in rows {
        let g = |o: &Option<IncastRun>| match o {
            Some(x) => format!("{:.2}", x.goodput_gbps),
            None => "-".into(),
        };
        let p = |o: &Option<IncastRun>| match o {
            Some(x) => format!("{:.1}", x.p99_fct_us),
            None => "-".into(),
        };
        let marks = r.dcqcn.map(|x| x.ecn_marks).unwrap_or(0);
        let drops = r.no_cc.or(r.dcqcn).map(|x| x.switch_drops).unwrap_or(0);
        let pauses = r.pfc.map(|x| x.pauses).unwrap_or(0);
        out.push_str(&format!(
            "{:>7} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
            r.oversub,
            g(&r.dcqcn),
            g(&r.no_cc),
            g(&r.pfc),
            p(&r.dcqcn),
            p(&r.no_cc),
            p(&r.pfc),
            marks,
            drops,
            pauses
        ));
    }
    out
}

/// The Fig-13 [`Series`] (shared by the CLI and the determinism tests).
pub fn fig13_series(rows: &[Fig13Row]) -> Series {
    let mut s = Series::new(
        "fig13_incast",
        "oversub",
        &[
            "dcqcn_goodput_gbps",
            "nocc_goodput_gbps",
            "pfc_goodput_gbps",
            "dcqcn_p50_fct_us",
            "nocc_p50_fct_us",
            "pfc_p50_fct_us",
            "dcqcn_p99_fct_us",
            "nocc_p99_fct_us",
            "pfc_p99_fct_us",
            "dcqcn_ecn_marks",
            "dcqcn_switch_drops",
            "nocc_switch_drops",
            "pfc_pauses",
            "dcqcn_retransmits",
            "nocc_retransmits",
            "nocc_retry_exceeded",
        ],
    );
    for r in rows {
        let d = |f: fn(&IncastRun) -> f64| r.dcqcn.as_ref().map(f).unwrap_or(f64::NAN);
        let n = |f: fn(&IncastRun) -> f64| r.no_cc.as_ref().map(f).unwrap_or(f64::NAN);
        let pf = |f: fn(&IncastRun) -> f64| r.pfc.as_ref().map(f).unwrap_or(f64::NAN);
        s.push(
            r.oversub as f64,
            vec![
                d(|x| x.goodput_gbps),
                n(|x| x.goodput_gbps),
                pf(|x| x.goodput_gbps),
                d(|x| x.p50_fct_us),
                n(|x| x.p50_fct_us),
                pf(|x| x.p50_fct_us),
                d(|x| x.p99_fct_us),
                n(|x| x.p99_fct_us),
                pf(|x| x.p99_fct_us),
                d(|x| x.ecn_marks as f64),
                d(|x| x.switch_drops as f64),
                n(|x| x.switch_drops as f64),
                pf(|x| x.pauses as f64),
                d(|x| x.retransmits as f64),
                n(|x| x.retransmits as f64),
                n(|x| x.retry_exceeded as f64),
            ],
        );
    }
    s
}

// ------------------------------------------------------------------ Fig 14

/// The fig-14 [`FailoverCfg`] (shared with `bench failover` so
/// BENCH_PR10.json times exactly the runs the figure makes).
pub fn fig14_cfg(budget: Budget, repath: bool) -> FailoverCfg {
    let mut cfg = FailoverCfg::default();
    cfg.repath = repath;
    if budget == Budget::Quick {
        cfg.writers = 6;
        cfg.mice = 2;
        cfg.window = 4;
        // the failure window must still outlast the ~1.2ms retry budget
        // (so the ablation produces RetryExceeded) — shrink around it
        cfg.fail_from = 1_000_000;
        cfg.fail_until = 3_000_000;
        cfg.duration = Ns::from_ms(6);
    }
    cfg
}

/// One fig-14 row: the same failover tape with the survivability
/// machinery on (repath + heal) and off (the ablation).
#[derive(Clone, Debug)]
pub struct Fig14Row {
    /// Repath + self-healing on.
    pub repath: Option<FailoverRun>,
    /// The ablation: frozen routing mask, no detector, no healing.
    pub no_repath: Option<FailoverRun>,
}

/// Fig 14: goodput through a spine failure + uplink death, repath on vs
/// off. Two independent `Sim`s, interleaved under `--jobs`.
pub fn fig14(budget: Budget, jobs: usize) -> Vec<Fig14Row> {
    fig14_sharded(budget, jobs, 1)
}

/// [`fig14`] with a sharded `Sim` per run (shard-invariant output).
pub fn fig14_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig14Row> {
    let runs = parallel::map_indexed(vec![true, false], jobs, |_, repath| {
        let mut cfg = fig14_cfg(budget, repath);
        cfg.shards = shards;
        failover_storm(&cfg)
    });
    let mut it = runs.into_iter();
    vec![Fig14Row { repath: it.next(), no_repath: it.next() }]
}

/// The `--repath-off` ablation alone.
pub fn fig14_repath_off(budget: Budget, jobs: usize) -> Vec<Fig14Row> {
    fig14_repath_off_sharded(budget, jobs, 1)
}

/// [`fig14_repath_off`] with a sharded `Sim` (shard-invariant).
pub fn fig14_repath_off_sharded(budget: Budget, jobs: usize, shards: usize) -> Vec<Fig14Row> {
    let runs = parallel::map_indexed(vec![false], jobs, |_, repath| {
        let mut cfg = fig14_cfg(budget, repath);
        cfg.shards = shards;
        failover_storm(&cfg)
    });
    vec![Fig14Row { repath: None, no_repath: runs.into_iter().next() }]
}

/// Render the Fig-14 table: phase goodputs and recovery counters, then
/// the goodput timeline of both runs.
pub fn print_fig14(rows: &[Fig14Row]) -> String {
    let mut out = String::new();
    out.push_str("Fig 14: failover storm — goodput through a spine death, repath on vs off\n");
    out.push_str(&format!(
        "{:>10} {:>8} {:>8} {:>8} {:>9} {:>8} {:>7} {:>7} {:>8} {:>7}\n",
        "mode", "pre Gb", "dip Gb", "post Gb", "p99 us", "repaths", "epochs", "heals", "retryex",
        "alive"
    ));
    let line = |out: &mut String, label: &str, r: &FailoverRun| {
        out.push_str(&format!(
            "{:>10} {:>8.2} {:>8.2} {:>8.2} {:>9.1} {:>8} {:>7} {:>7} {:>8} {:>7}\n",
            label,
            r.pre_gbps,
            r.dip_gbps,
            r.post_gbps,
            r.p99_fct_us,
            r.repaths,
            r.route_epoch,
            r.qp_reestablished,
            r.retry_exceeded,
            r.flows_alive
        ));
    };
    for row in rows {
        if let Some(r) = &row.repath {
            line(&mut out, "repath", r);
        }
        if let Some(r) = &row.no_repath {
            line(&mut out, "no-repath", r);
        }
    }
    // the goodput timeline, one bin per line (the figure's x axis)
    let tl = |out: &mut String, label: &str, r: &FailoverRun| {
        out.push_str(&format!("timeline ({label}), Gb/s per {}us bin:\n", FAILOVER_BIN_NS / 1000));
        for (i, g) in r.timeline_gbps.iter().enumerate() {
            out.push_str(&format!("  {:>6.2}ms {:>8.2}\n", (i as u64 * FAILOVER_BIN_NS) as f64 / 1e6, g));
        }
    };
    for row in rows {
        if let Some(r) = &row.repath {
            tl(&mut out, "repath", r);
        }
        if let Some(r) = &row.no_repath {
            tl(&mut out, "no-repath", r);
        }
    }
    out
}

/// The Fig-14 [`Series`] (shared by the CLI and the determinism tests):
/// one point per timeline bin, with the phase scalars repeated so the
/// TSV stays self-describing.
pub fn fig14_series(rows: &[Fig14Row]) -> Series {
    let mut s = Series::new(
        "fig14_failover",
        "time_ms",
        &[
            "repath_gbps",
            "norepath_gbps",
            "repath_pre_gbps",
            "repath_post_gbps",
            "norepath_post_gbps",
            "repath_p99_fct_us",
            "norepath_p99_fct_us",
            "repaths",
            "route_epoch",
            "qp_reestablished",
            "heal_backoff_ms",
            "repath_retry_exceeded",
            "norepath_retry_exceeded",
            "repath_flows_alive",
            "norepath_flows_alive",
        ],
    );
    for row in rows {
        let on = row.repath.as_ref();
        let off = row.no_repath.as_ref();
        let nbins = on
            .map(|r| r.timeline_gbps.len())
            .max(off.map(|r| r.timeline_gbps.len()))
            .unwrap_or(0);
        for i in 0..nbins {
            let bin = |r: Option<&FailoverRun>| {
                r.and_then(|x| x.timeline_gbps.get(i)).copied().unwrap_or(f64::NAN)
            };
            let f = |r: Option<&FailoverRun>, g: fn(&FailoverRun) -> f64| {
                r.map(g).unwrap_or(f64::NAN)
            };
            s.push(
                (i as u64 * FAILOVER_BIN_NS) as f64 / 1e6,
                vec![
                    bin(on),
                    bin(off),
                    f(on, |x| x.pre_gbps),
                    f(on, |x| x.post_gbps),
                    f(off, |x| x.post_gbps),
                    f(on, |x| x.p99_fct_us),
                    f(off, |x| x.p99_fct_us),
                    f(on, |x| x.repaths as f64),
                    f(on, |x| x.route_epoch as f64),
                    f(on, |x| x.qp_reestablished as f64),
                    f(on, |x| x.heal_backoff_ns as f64 / 1e6),
                    f(on, |x| x.retry_exceeded as f64),
                    f(off, |x| x.retry_exceeded as f64),
                    f(on, |x| x.flows_alive as f64),
                    f(off, |x| x.flows_alive as f64),
                ],
            );
        }
    }
    s
}

// --------------------------------------------------------- figure runner

/// Run one figure id end-to-end; returns its [`Series`] plus the rendered
/// paper-shaped table (callers choose the stream the table goes to).
/// Figures 7 and 8 come from one shared sweep, memoized in `fig78_cache`
/// so asking for both runs it once. `jobs` fans the sweep points out
/// across threads (1 = the serial runner, byte-identical output either
/// way). Unknown ids return None.
pub fn run_fig(
    id: u64,
    b: Budget,
    fig78_cache: &mut Option<Vec<Fig78Row>>,
    jobs: usize,
) -> Option<(Series, String)> {
    run_fig_sharded(id, b, fig78_cache, jobs, 1)
}

/// [`run_fig`] with a sharded `Sim` per sweep point. Only the daemon-scale
/// figures (9–14) thread the knob — figs 1–8 run tiny fabrics where
/// partitioning has nothing to win, so they ignore it. The output bytes
/// are identical for every `shards` value (the determinism suite gates
/// figs 9–14 at `shards = 4` against serial), so the figure JSON never
/// records the knob.
pub fn run_fig_sharded(
    id: u64,
    b: Budget,
    fig78_cache: &mut Option<Vec<Fig78Row>>,
    jobs: usize,
    shards: usize,
) -> Option<(Series, String)> {
    match id {
        1 => {
            let rows = fig1(b, jobs);
            let table = print_fig1(&rows);
            let mut s = Series::new(
                "fig1_verbs",
                "msg_bytes",
                &["rc_read", "rc_write", "uc_write", "ud_send"],
            );
            for r in &rows {
                s.push(r.msg_bytes as f64, vec![r.rc_read, r.rc_write, r.uc_write, r.ud_send]);
            }
            Some((s, table))
        }
        5 => {
            let rows = fig5(b, jobs);
            let table = print_fig5(&rows);
            let mut s = Series::new(
                "fig5_scalability",
                "conns",
                &["naive_gbps", "raas_gbps", "naive_cache", "raas_cache"],
            );
            for r in &rows {
                s.push(
                    r.conns as f64,
                    vec![r.naive.gbps, r.raas.gbps, r.naive.cache_hit_rate, r.raas.cache_hit_rate],
                );
            }
            Some((s, table))
        }
        6 => {
            let rows = fig6(b, jobs);
            let table = print_fig6(&rows);
            let mut s = Series::new(
                "fig6_qp_sharing",
                "threads",
                &["raas_mops", "lock_q3_mops", "lock_q6_mops"],
            );
            for r in &rows {
                s.push(r.threads as f64, vec![r.raas.mops, r.locked_q3.mops, r.locked_q6.mops]);
            }
            Some((s, table))
        }
        7 => {
            let rows = fig78_cache.get_or_insert_with(|| fig78(b, jobs)).clone();
            let table = print_fig7(&rows);
            let mut s = Series::new("fig7_memory", "apps", &["naive_mem", "raas_mem"]);
            for r in &rows {
                s.push(r.apps as f64, vec![r.naive_mem, r.raas_mem]);
            }
            Some((s, table))
        }
        8 => {
            let rows = fig78_cache.get_or_insert_with(|| fig78(b, jobs)).clone();
            let table = print_fig8(&rows);
            let mut s = Series::new("fig8_cpu", "apps", &["naive_cpu", "raas_cpu"]);
            for r in &rows {
                s.push(r.apps as f64, vec![r.naive_cpu, r.raas_cpu]);
            }
            Some((s, table))
        }
        9 => {
            let rows = fig9_sharded(b, jobs, shards);
            let table = print_fig9(&rows);
            Some((fig9_series(&rows), table))
        }
        10 => {
            let rows = fig10_sharded(b, jobs, shards);
            let table = print_fig10(&rows);
            Some((fig10_series(&rows), table))
        }
        11 => {
            let rows = fig11_sharded(b, jobs, shards);
            let table = print_fig11(&rows);
            Some((fig11_series(&rows), table))
        }
        12 => {
            let rows = fig12_sharded(b, jobs, shards);
            let table = print_fig12(&rows);
            Some((fig12_series(&rows), table))
        }
        13 => {
            let rows = fig13_sharded(b, jobs, shards);
            let table = print_fig13(&rows);
            Some((fig13_series(&rows), table))
        }
        14 => {
            let rows = fig14_sharded(b, jobs, shards);
            let table = print_fig14(&rows);
            Some((fig14_series(&rows), table))
        }
        _ => None,
    }
}

// ------------------------------------------------------- §2.2 ablation

/// memcpy-vs-memreg staging crossover (Frey & Alonso [9]); the ablation
/// behind RDMAvisor's decision not to offer send_zero_copy.
pub fn send_staging_sweep() -> String {
    use crate::raas::buffer::{Staging, StagingCosts};
    let costs = StagingCosts::default();
    let mut out = String::new();
    out.push_str("§2.2 send staging: memcpy vs memreg cost (ns) by size\n");
    out.push_str(&format!("{:>10} {:>10} {:>10} {:>8}\n", "size", "memcpy", "memreg", "choice"));
    for &sz in &[4096u64, 16 << 10, 64 << 10, 128 << 10, 150_000, 256 << 10, 1 << 20, 4 << 20] {
        let choice = costs.choose(sz);
        out.push_str(&format!(
            "{:>10} {:>10} {:>10} {:>8}\n",
            human_size(sz),
            costs.cost_ns(Staging::Memcpy, sz),
            costs.cost_ns(Staging::Memreg, sz),
            match choice {
                Staging::Memcpy => "memcpy",
                Staging::Memreg => "memreg",
            }
        ));
    }
    out.push_str(&format!("crossover = {} bytes\n", costs.crossover_bytes()));
    out
}

/// WR-batching ablation: RaaS with batch_max=1 vs default (the §2.3 claim
/// that QP sharing raises batching opportunity and thus throughput).
pub fn batching_ablation(budget: Budget) -> String {
    use crate::raas::daemon::DaemonConfig;
    let mut out = String::new();
    out.push_str("Ablation: WR batching (RaaS, 400 conns, 4 KB reads)\n");
    for (label, batch) in [("batch=1", 1usize), ("batch=32", 32)] {
        let mut cfg = ScenarioCfg::default();
        cfg.conns = 400;
        cfg.msg_bytes = 4096;
        cfg.window = 2;
        cfg.duration = budget.duration();
        let st = crate::workload::scenarios::raas_random_read_with_daemon(
            &cfg,
            DaemonConfig { batch_max: batch, ..DaemonConfig::default() },
        );
        out.push_str(&format!("  {label:<10} {:>8.2} Gb/s  {:>8.3} Mops\n", st.gbps, st.mops));
    }
    out
}

/// `4096` → `"4KB"` — the tables' size formatter.
pub fn human_size(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{}MB", b >> 20)
    } else if b >= 1 << 10 {
        format!("{}KB", b >> 10)
    } else {
        format!("{b}B")
    }
}

/// Default fabric config accessor for the CLI.
pub fn default_fabric() -> FabricConfig {
    FabricConfig::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_the_matrix() {
        let t = table1();
        assert!(t.contains("RC"));
        assert!(t.contains("1GB"));
        assert!(t.contains("MTU"));
        // UC row must not claim READ support
        let uc_line = t.lines().find(|l| l.starts_with("UC")).unwrap();
        assert!(uc_line.contains('-'));
    }

    #[test]
    fn human_sizes() {
        assert_eq!(human_size(64), "64B");
        assert_eq!(human_size(4096), "4KB");
        assert_eq!(human_size(1 << 20), "1MB");
    }

    #[test]
    fn staging_sweep_has_crossover() {
        let s = send_staging_sweep();
        assert!(s.contains("memcpy"));
        assert!(s.contains("memreg"));
        assert!(s.contains("crossover = 150000"));
    }
}
