//! FaRM-style locked QP sharing [8]: each QP shared by `q` threads.
//!
//! QP count drops to `threads / q` (good for the NIC cache), but every
//! post serializes through the QP's mutex and the lock cache line bounces
//! between the `q` contending cores — the degradation Fig 6 shows for
//! q=3 vs q=6, which RDMAvisor's lock-free rings avoid.
//!
//! The lock is modeled with [`MutexModel`]: single-server queueing at the
//! lock plus a per-contender coherence penalty. A thread whose completion
//! arrives at `t` re-posts at `lock_grant(t) + hold`; the driver gets the
//! grant times through [`Sim::schedule`] timers.

use crate::fabric::cpu::MutexModel;
use crate::fabric::mr::{Access, MemoryRegion};
use crate::fabric::sim::Sim;
use crate::fabric::time::Ns;
use crate::fabric::types::{Cqn, NodeId, QpTransport, Qpn};
use crate::fabric::verbs;
use crate::fabric::wqe::SendWr;

/// One worker thread bound to a shared QP.
pub struct LockedThread {
    /// Index into [`LockedSystem::qps`] this thread posts on.
    pub qp_index: usize,
    /// Remote node this thread's QP targets.
    pub remote: NodeId,
    /// Outstanding ops posted by this thread.
    pub inflight: u32,
    /// Lifetime completions for this thread.
    pub completed_ops: u64,
}

/// One shared QP with its mutex.
pub struct SharedQp {
    /// The shared QP.
    pub qpn: Qpn,
    /// Remote node the QP connects to.
    pub remote: NodeId,
    /// The contended post lock (Fig 6's bottleneck).
    pub mutex: MutexModel,
    /// Server-side buffer the q sharers read from.
    pub remote_buf: MemoryRegion,
}

/// The locked-sharing client stack.
pub struct LockedSystem {
    /// Client node the stack runs on.
    pub node: NodeId,
    /// One shared CQ for all threads (single poller).
    pub cq: Cqn,
    /// Threads sharing each QP.
    pub q: usize,
    /// The shared QPs (`threads / q` of them).
    pub qps: Vec<SharedQp>,
    /// Worker-thread states.
    pub threads: Vec<LockedThread>,
    /// One client-side landing buffer shared by all threads.
    pub local_buf: MemoryRegion,
    /// CPU ns each post burns while holding the lock (WQE build + doorbell).
    pub hold_ns: u64,
    /// Time threads spent blocked on locks (Fig 6's wasted CPU).
    pub lock_wait_ns: u64,
    /// Poll scratch buffer reused across calls (zero-alloc CQ drain).
    cqe_buf: Vec<crate::fabric::wqe::Cqe>,
}

impl LockedSystem {
    /// `threads` worker threads share QPs in groups of `q`; QPs fan out
    /// round-robin over `servers`.
    pub fn setup(
        sim: &mut Sim,
        client: NodeId,
        servers: &[NodeId],
        threads: usize,
        q: usize,
        buf_bytes: u64,
    ) -> LockedSystem {
        assert!(q >= 1);
        let cq = sim.create_cq(client, 65_536);
        // one polling thread for the app (same as RaaS's poller budget)
        sim.node_mut(client).cpu.polling_threads += 1;
        let n_qps = threads.div_ceil(q);
        let local_buf = sim.reg_mr(client, (threads as u64) * buf_bytes, Access::REMOTE_RW, true);
        let mut qps = Vec::new();
        for i in 0..n_qps {
            let remote = servers[i % servers.len()];
            let server_cq = sim.create_cq(remote, 4096);
            let pair = verbs::create_connected_pair(
                sim, QpTransport::Rc, client, remote, cq, cq, server_cq, server_cq,
            );
            let remote_buf = sim.reg_mr(remote, buf_bytes * q as u64, Access::REMOTE_RW, true);
            qps.push(SharedQp { qpn: pair.a.1, remote, mutex: MutexModel::new(), remote_buf });
        }
        let threads = (0..threads)
            .map(|t| LockedThread {
                qp_index: t / q,
                remote: qps[t / q].remote,
                inflight: 0,
                completed_ops: 0,
            })
            .collect();
        LockedSystem {
            node: client,
            cq,
            q,
            qps,
            threads,
            local_buf,
            hold_ns: 400,
            lock_wait_ns: 0,
            cqe_buf: Vec::new(),
        }
    }

    /// Thread `t` wants to post a READ *now*; it must win the QP mutex
    /// first. Returns the lock-grant time — call [`Self::post_read_at`]
    /// when the sim reaches it (via a [`Sim::schedule`] timer).
    pub fn acquire_for_post(&mut self, now: Ns, t: usize) -> Ns {
        let thread = &self.threads[t];
        let qp = &mut self.qps[thread.qp_index];
        let (start, end) = qp.mutex.acquire(now, self.hold_ns, self.q);
        self.lock_wait_ns += start.0.saturating_sub(now.0);
        end
    }

    /// Execute the post after the lock was granted.
    pub fn post_read_at(&mut self, sim: &mut Sim, t: usize, len: u64, offset: u64) {
        let thread = &mut self.threads[t];
        let qp = &self.qps[thread.qp_index];
        let off = offset % (qp.remote_buf.len - len).max(1);
        let wr = SendWr::read(
            t as u64,
            len,
            self.local_buf.key,
            self.local_buf.addr + (t as u64) * len,
            qp.remote_buf.key,
            qp.remote_buf.addr + off,
        );
        // the critical section burns CPU on the posting core
        sim.node_mut(self.node).cpu.charge(self.hold_ns + 25);
        sim.post_send(self.node, qp.qpn, wr).expect("locked post_read");
        thread.inflight += 1;
    }

    /// Poll the shared CQ; returns thread ids whose ops completed.
    pub fn poll(&mut self, sim: &mut Sim) -> Vec<usize> {
        let mut ready = Vec::new();
        self.cqe_buf.clear();
        sim.poll_cq_into(self.node, self.cq, 64, &mut self.cqe_buf);
        for cqe in &self.cqe_buf {
            let t = cqe.wr_id as usize;
            if let Some(thread) = self.threads.get_mut(t) {
                thread.inflight = thread.inflight.saturating_sub(1);
                thread.completed_ops += 1;
                ready.push(t);
            }
        }
        ready
    }

    /// Number of shared QPs (`threads / q`, rounded up).
    pub fn qp_count(&self) -> usize {
        self.qps.len()
    }

    /// Aggregate contended time across all QP mutexes.
    pub fn total_contended_ns(&self) -> u64 {
        self.qps.iter().map(|q| q.mutex.contended_ns_total).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;

    #[test]
    fn qp_count_is_threads_over_q() {
        let mut sim = Sim::new(FabricConfig::default());
        let servers = [NodeId(1), NodeId(2), NodeId(3)];
        let sys = LockedSystem::setup(&mut sim, NodeId(0), &servers, 12, 3, 64 << 10);
        assert_eq!(sys.qp_count(), 4);
        let sys6 = LockedSystem::setup(&mut sim, NodeId(0), &servers, 12, 6, 64 << 10);
        assert_eq!(sys6.qp_count(), 2);
    }

    #[test]
    fn lock_serializes_concurrent_posters() {
        let mut sim = Sim::new(FabricConfig::default());
        let servers = [NodeId(1)];
        let mut sys = LockedSystem::setup(&mut sim, NodeId(0), &servers, 6, 6, 64 << 10);
        // all six threads try to post at t=0 on the same QP
        let grants: Vec<Ns> = (0..6).map(|t| sys.acquire_for_post(Ns(0), t)).collect();
        for w in grants.windows(2) {
            assert!(w[1] > w[0], "grants must serialize: {grants:?}");
        }
        assert!(sys.lock_wait_ns > 0);
        // per-grant spacing grows with q (coherence penalty)
        let spacing_q6 = grants[1].0 - grants[0].0;
        let mut sys3 = LockedSystem::setup(&mut sim, NodeId(0), &servers, 6, 3, 64 << 10);
        let g3: Vec<Ns> = (0..3).map(|t| sys3.acquire_for_post(Ns(0), t)).collect();
        let spacing_q3 = g3[1].0 - g3[0].0;
        assert!(spacing_q6 > spacing_q3, "q=6 lock slower than q=3");
    }

    #[test]
    fn end_to_end_read_through_locked_qp() {
        let mut sim = Sim::new(FabricConfig::default());
        let servers = [NodeId(1)];
        let mut sys = LockedSystem::setup(&mut sim, NodeId(0), &servers, 3, 3, 256 << 10);
        // post via the lock protocol: acquire, schedule, post on grant
        for t in 0..3 {
            let grant = sys.acquire_for_post(sim.now(), t);
            sim.schedule(grant, t as u64);
        }
        let mut completed = 0;
        for _ in 0..200_000 {
            let Some(notes) = sim.step() else { break };
            for n in notes {
                match n {
                    crate::fabric::sim::Notification::Timer { token } => {
                        sys.post_read_at(&mut sim, token as usize, 64 << 10, 0);
                    }
                    crate::fabric::sim::Notification::CqeReady { .. } => {
                        completed += sys.poll(&mut sim).len();
                    }
                }
            }
        }
        completed += sys.poll(&mut sim).len();
        assert_eq!(completed, 3);
    }
}
