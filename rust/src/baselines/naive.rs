//! Naive RDMA baseline: one QP per connection, no sharing.
//!
//! Every logical connection gets its own RC QP pair, its own registered
//! staging buffer on each side, and each *application* runs its own
//! busy-polling completion thread over its own CQ. This is the "naive
//! RDMA Read verbs where the QPs are not shared by connections" system of
//! Fig 5 and the per-application resource fleet of Figs 7/8.

use crate::fabric::mr::{Access, MemoryRegion};
use crate::fabric::sim::Sim;
use crate::fabric::types::{Cqn, NodeId, QpTransport, Qpn};
use crate::fabric::verbs;
use crate::fabric::wqe::SendWr;

/// One naive connection: exclusive QP + buffers.
pub struct NaiveConn {
    /// Owning application.
    pub app: u32,
    /// Remote node this connection targets.
    pub remote: NodeId,
    /// The connection's exclusive QP.
    pub qpn: Qpn,
    /// Private client-side registered buffer.
    pub local_buf: MemoryRegion,
    /// Private server-side registered buffer.
    pub remote_buf: MemoryRegion,
    /// Outstanding ops on this connection.
    pub inflight: u32,
    /// Lifetime completions on this connection.
    pub completed_ops: u64,
}

/// The naive client stack on one node.
pub struct NaiveSystem {
    /// Client node the stack runs on.
    pub node: NodeId,
    /// One CQ per application (polled by that app's dedicated thread).
    pub app_cqs: Vec<Cqn>,
    /// All connections, across all apps.
    pub conns: Vec<NaiveConn>,
    /// Per-conn buffer bytes (both sides), for the memory ledger.
    pub buf_bytes_per_conn: u64,
    /// Poll scratch buffer reused across calls (zero-alloc CQ drain).
    cqe_buf: Vec<crate::fabric::wqe::Cqe>,
}

impl NaiveSystem {
    /// Stand up `n_apps` applications on `client`; each opens
    /// `conns_per_app` connections spread round-robin over `servers`.
    /// Every app's polling thread pins a core (Fig 8's linear growth).
    pub fn setup(
        sim: &mut Sim,
        client: NodeId,
        servers: &[NodeId],
        n_apps: u32,
        conns_per_app: u32,
        buf_bytes: u64,
    ) -> NaiveSystem {
        let mut app_cqs = Vec::new();
        let mut conns = Vec::new();
        for app in 0..n_apps {
            let cq = sim.create_cq(client, 4096);
            app_cqs.push(cq);
            // each app burns one busy-poll core (its Poller-equivalent)
            sim.node_mut(client).cpu.polling_threads += 1;
            for c in 0..conns_per_app {
                let remote = servers[((app * conns_per_app + c) as usize) % servers.len()];
                let server_cq = sim.create_cq(remote, 4096);
                let pair = verbs::create_connected_pair(
                    sim,
                    QpTransport::Rc,
                    client,
                    remote,
                    cq,
                    cq,
                    server_cq,
                    server_cq,
                );
                let local_buf = sim.reg_mr(client, buf_bytes, Access::REMOTE_RW, true);
                let remote_buf = sim.reg_mr(remote, buf_bytes, Access::REMOTE_RW, true);
                conns.push(NaiveConn {
                    app,
                    remote,
                    qpn: pair.a.1,
                    local_buf,
                    remote_buf,
                    inflight: 0,
                    completed_ops: 0,
                });
            }
        }
        NaiveSystem {
            node: client,
            app_cqs,
            conns,
            buf_bytes_per_conn: 2 * buf_bytes,
            cqe_buf: Vec::new(),
        }
    }

    /// Post one READ on connection `idx` at `offset`.
    pub fn post_read(&mut self, sim: &mut Sim, idx: usize, len: u64, offset: u64) {
        let conn = &mut self.conns[idx];
        let off = offset % (conn.remote_buf.len - len).max(1);
        let wr = SendWr::read(
            idx as u64,
            len,
            conn.local_buf.key,
            conn.local_buf.addr,
            conn.remote_buf.key,
            conn.remote_buf.addr + off,
        );
        sim.post_send(self.node, conn.qpn, wr).expect("naive post_read");
        conn.inflight += 1;
    }

    /// Post one WRITE on connection `idx`.
    pub fn post_write(&mut self, sim: &mut Sim, idx: usize, len: u64, offset: u64) {
        let conn = &mut self.conns[idx];
        let off = offset % (conn.remote_buf.len - len).max(1);
        let wr = SendWr::write(
            idx as u64,
            len,
            conn.local_buf.key,
            conn.local_buf.addr,
            conn.remote_buf.key,
            conn.remote_buf.addr + off,
        );
        sim.post_send(self.node, conn.qpn, wr).expect("naive post_write");
        conn.inflight += 1;
    }

    /// Poll every app CQ once; returns indices of connections whose ops
    /// completed (the driver re-posts on them — closed loop).
    pub fn poll(&mut self, sim: &mut Sim) -> Vec<usize> {
        let mut ready = Vec::new();
        for i in 0..self.app_cqs.len() {
            let cq = self.app_cqs[i];
            self.cqe_buf.clear();
            sim.poll_cq_into(self.node, cq, 64, &mut self.cqe_buf);
            for cqe in &self.cqe_buf {
                let idx = cqe.wr_id as usize;
                if let Some(conn) = self.conns.get_mut(idx) {
                    conn.inflight = conn.inflight.saturating_sub(1);
                    conn.completed_ops += 1;
                    ready.push(idx);
                }
            }
        }
        ready
    }

    /// Memory the naive stack consumes on the client (Fig 7): per-conn QP
    /// rings + contexts, per-app CQs, per-conn registered buffers + MTT.
    pub fn client_mem_bytes(&self, sim: &Sim) -> u64 {
        // all fabric objects + registered regions on the client node belong
        // to this stack (each connection owns its private buffer fleet)
        let node = sim.node(self.node);
        node.fabric_mem_bytes() + node.mrs.registered_bytes
    }

    /// Cores consumed on the client (Fig 8).
    pub fn client_cpu_cores(&self, sim: &Sim) -> f64 {
        let node = sim.node(self.node);
        node.cpu.cores_used(sim.now())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;

    fn servers() -> Vec<NodeId> {
        vec![NodeId(1), NodeId(2), NodeId(3)]
    }

    #[test]
    fn setup_creates_qp_per_connection() {
        let mut sim = Sim::new(FabricConfig::default());
        let sys = NaiveSystem::setup(&mut sim, NodeId(0), &servers(), 2, 10, 64 << 10);
        assert_eq!(sys.conns.len(), 20);
        assert_eq!(sim.node(NodeId(0)).qps.len(), 20, "one QP per conn");
        assert_eq!(sys.app_cqs.len(), 2);
        assert_eq!(sim.node(NodeId(0)).cpu.polling_threads, 2);
    }

    #[test]
    fn closed_loop_read_completes() {
        let mut sim = Sim::new(FabricConfig::default());
        let mut sys = NaiveSystem::setup(&mut sim, NodeId(0), &servers(), 1, 4, 256 << 10);
        for i in 0..4 {
            sys.post_read(&mut sim, i, 64 << 10, 0);
        }
        let mut done = 0;
        for _ in 0..100_000 {
            if sim.step().is_none() {
                break;
            }
            done += sys.poll(&mut sim).len();
        }
        done += sys.poll(&mut sim).len();
        assert_eq!(done, 4);
        assert_eq!(sim.completed_bytes, 4 * (64 << 10));
    }

    #[test]
    fn memory_scales_linearly_with_conns() {
        let mut sim1 = Sim::new(FabricConfig::default());
        let s1 = NaiveSystem::setup(&mut sim1, NodeId(0), &servers(), 1, 10, 64 << 10);
        let mut sim2 = Sim::new(FabricConfig::default());
        let s2 = NaiveSystem::setup(&mut sim2, NodeId(0), &servers(), 1, 40, 64 << 10);
        let m1 = s1.client_mem_bytes(&sim1);
        let m2 = s2.client_mem_bytes(&sim2);
        let ratio = m2 as f64 / m1 as f64;
        assert!(ratio > 3.0, "4x conns should be ~4x memory, got {ratio:.2}x");
    }
}
