//! The comparison systems of the paper's evaluation.
//!
//! * [`naive`] — "naive RDMA": one exclusive RC QP per connection, per-app
//!   CQ with a dedicated busy-poll thread, per-connection registered
//!   buffers. This is what Fig 5 collapses beyond ~400 QPs (NIC ICM cache
//!   thrash) and what Figs 7/8 show growing linearly per application.
//! * [`locked`] — FaRM-style QP sharing [8]: each QP is shared by `q`
//!   threads guarded by a mutex. Cuts the QP count (fixing Fig 5's cache
//!   problem) but serializes posts through locks, which Fig 6 shows
//!   degrading as `q` grows. RDMAvisor's lock-free vQPN design avoids both.

pub mod naive;
pub mod locked;
