//! Criterion-style micro/macro benchmark harness (criterion is unavailable
//! offline). Warms up, runs timed iterations until the mean converges or an
//! iteration budget is hit, and reports mean/p50/p99 plus derived throughput.
//!
//! The `[[bench]]` targets in Cargo.toml use `harness = false` and call
//! [`Bencher`] from `main`, so `cargo bench` runs these directly.

use std::time::{Duration, Instant};

use super::stats::{Histogram, Summary};

/// One benchmark result row.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Timed iterations executed.
    pub iters: u64,
    /// Mean iteration time, ns.
    pub mean_ns: f64,
    /// Median iteration time, ns.
    pub p50_ns: u64,
    /// 99th-percentile iteration time, ns.
    pub p99_ns: u64,
    /// Fastest iteration, ns.
    pub min_ns: u64,
    /// Slowest iteration, ns.
    pub max_ns: u64,
    /// Optional user-supplied scalar (e.g. simulated Gb/s) reported alongside.
    pub metric: Option<(String, f64)>,
}

impl BenchResult {
    /// Print the row in the harness's standard format.
    pub fn print(&self) {
        let metric = match &self.metric {
            Some((name, v)) => format!("  {name}={v:.3}"),
            None => String::new(),
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns as f64),
            fmt_ns(self.p99_ns as f64),
            metric
        );
    }
}

/// Human formatting for a nanosecond quantity (`1234.0` → `"1.23 µs"`).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Untimed warmup budget before measurement.
    pub warmup: Duration,
    /// Wall-clock budget for the timed phase.
    pub max_time: Duration,
    /// Never stop before this many iterations.
    pub min_iters: u64,
    /// Hard iteration cap.
    pub max_iters: u64,
    /// Convergence: stop when the relative stderr of the mean drops below this.
    pub target_rse: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            max_time: Duration::from_secs(3),
            min_iters: 10,
            max_iters: 1_000_000,
            target_rse: 0.01,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Default harness (see [`Default`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI (`RDMAVISOR_BENCH_QUICK=1`): tighter budgets.
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if std::env::var("RDMAVISOR_BENCH_QUICK").is_ok() {
            b.warmup = Duration::from_millis(20);
            b.max_time = Duration::from_millis(300);
            b.min_iters = 3;
        }
        b
    }

    /// Time `f` repeatedly; each call is one iteration.
    pub fn bench<F: FnMut() -> R, R>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed.
        let mut sum = Summary::new();
        let mut hist = Histogram::new();
        let t0 = Instant::now();
        let mut iters = 0;
        while iters < self.max_iters {
            let s = Instant::now();
            std::hint::black_box(f());
            let ns = s.elapsed().as_nanos() as u64;
            sum.add(ns as f64);
            hist.record(ns);
            iters += 1;
            if iters >= self.min_iters
                && (t0.elapsed() > self.max_time || sum.rel_stderr() < self.target_rse)
            {
                break;
            }
        }
        self.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: sum.mean(),
            p50_ns: hist.p50(),
            p99_ns: hist.p99(),
            min_ns: hist.min(),
            max_ns: hist.max(),
            metric: None,
        })
    }

    /// Benchmark where `f` returns a user metric to aggregate (mean).
    pub fn bench_with_metric<F>(&mut self, name: &str, metric_name: &str, mut f: F) -> &BenchResult
    where
        F: FnMut() -> f64,
    {
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut sum = Summary::new();
        let mut hist = Histogram::new();
        let mut msum = Summary::new();
        let t0 = Instant::now();
        let mut iters = 0;
        while iters < self.max_iters {
            let s = Instant::now();
            let m = f();
            let ns = s.elapsed().as_nanos() as u64;
            msum.add(m);
            sum.add(ns as f64);
            hist.record(ns);
            iters += 1;
            if iters >= self.min_iters && t0.elapsed() > self.max_time {
                break;
            }
        }
        let metric = Some((metric_name.to_string(), msum.mean()));
        self.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: sum.mean(),
            p50_ns: hist.p50(),
            p99_ns: hist.p99(),
            min_ns: hist.min(),
            max_ns: hist.max(),
            metric,
        })
    }

    fn push(&mut self, r: BenchResult) -> &BenchResult {
        r.print();
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// All rows recorded by this harness.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all rows as TSV (consumed by EXPERIMENTS.md tables).
    pub fn write_tsv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name\titers\tmean_ns\tp50_ns\tp99_ns\tmin_ns\tmax_ns\tmetric")?;
        for r in &self.results {
            let metric = r
                .metric
                .as_ref()
                .map(|(k, v)| format!("{k}={v}"))
                .unwrap_or_default();
            writeln!(
                f,
                "{}\t{}\t{:.1}\t{}\t{}\t{}\t{}\t{}",
                r.name, r.iters, r.mean_ns, r.p50_ns, r.p99_ns, r.min_ns, r.max_ns, metric
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            max_time: Duration::from_millis(30),
            min_iters: 5,
            ..Default::default()
        };
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn metric_aggregated() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            max_time: Duration::from_millis(10),
            min_iters: 3,
            ..Default::default()
        };
        let r = b.bench_with_metric("m", "gbps", || 37.5);
        let (name, v) = r.metric.clone().unwrap();
        assert_eq!(name, "gbps");
        assert!((v - 37.5).abs() < 1e-9);
    }

    #[test]
    fn tsv_written() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            max_time: Duration::from_millis(5),
            min_iters: 2,
            ..Default::default()
        };
        b.bench("x", || 1);
        let path = std::env::temp_dir().join("rdmavisor_bench_test.tsv");
        b.write_tsv(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("name\t"));
        assert!(body.contains('x'));
    }
}
