//! Minimal TOML-subset parser for experiment/cluster config files.
//!
//! Supports: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / flat-array values, `#` comments.
//! Keys are flattened to `section.sub.key` in one map — enough for our
//! config surface, with precise error lines for anything unsupported.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Table {
    map: BTreeMap<String, Value>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Parse a TOML-subset document. Errors carry the 1-based line number.
pub fn parse(input: &str) -> Result<Table, String> {
    let mut table = Table::default();
    let mut prefix = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            prefix = format!("{name}.");
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = format!("{prefix}{}", k.trim());
        let value = parse_value(v.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        table.map.insert(key, value);
    }
    Ok(table)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: no '#' inside our string values
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<Value, String> {
    if tok.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = tok.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = tok.strip_prefix('[') {
        let inner = body.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|t| parse_value(t.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    let clean = tok.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value: {tok}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let t = parse("a = 1\nb = \"x\"\nc = 2.5\nd = true\n").unwrap();
        assert_eq!(t.int_or("a", 0), 1);
        assert_eq!(t.str_or("b", ""), "x");
        assert!((t.float_or("c", 0.0) - 2.5).abs() < 1e-12);
        assert!(t.bool_or("d", false));
    }

    #[test]
    fn sections_flatten() {
        let t = parse("[nic]\ncache = 400\n[link.a]\nrate = 40\n").unwrap();
        assert_eq!(t.int_or("nic.cache", 0), 400);
        assert_eq!(t.int_or("link.a.rate", 0), 40);
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse("# header\n\na = 1  # trailing\n").unwrap();
        assert_eq!(t.int_or("a", 0), 1);
    }

    #[test]
    fn arrays() {
        let t = parse("sizes = [64, 4096, 65536]\n").unwrap();
        let arr = t.get("sizes").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_int(), Some(65536));
    }

    #[test]
    fn underscore_numbers() {
        let t = parse("n = 1_000_000\n").unwrap();
        assert_eq!(t.int_or("n", 0), 1_000_000);
    }

    #[test]
    fn error_lines_reported() {
        let err = parse("a = 1\nbogus line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(t.str_or("s", ""), "a#b");
    }
}
