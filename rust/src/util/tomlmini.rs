//! Minimal TOML-subset parser + writer for experiment/cluster config files.
//!
//! Supports: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / flat-array values, `#` comments.
//! Keys are flattened to `section.sub.key` in one map — enough for our
//! config surface, with precise error lines for anything unsupported.
//! [`write`] serializes a [`Table`] back to parseable text, so configs
//! round-trip (`parse(write(parse(doc))) == parse(doc)` — covered by
//! `tests/minilang_roundtrip.rs`).

use std::collections::BTreeMap;

/// A TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A double-quoted string (no escape sequences in the subset).
    Str(String),
    /// An integer (underscore separators accepted).
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer value, if this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// The numeric value (floats and integers both coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: flattened `section.sub.key -> value` map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    map: BTreeMap<String, Value>,
}

impl Table {
    /// Value of a flattened `section.key`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// String at `key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    /// Integer at `key`, or `default`.
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    /// Float (or integer) at `key`, or `default`.
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    /// Boolean at `key`, or `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All flattened keys, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when the table has no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Insert (or overwrite) a flattened `section.key` entry.
    pub fn set(&mut self, key: &str, value: Value) {
        self.map.insert(key.to_string(), value);
    }
}

/// Serialize a [`Table`] back to TOML-subset text that [`parse`] accepts.
///
/// Dot-free keys come first (top level); the rest are grouped under
/// `[section]` headers (the section is everything up to the last dot).
/// Floats always carry a decimal point so their type survives re-parsing.
/// Values the subset grammar cannot represent are degraded so the output
/// still parses: string characters that would break the quoting (`"`,
/// newlines; `,`/`]` inside arrays) become `_`, and non-finite floats
/// become `0.0`.
pub fn write(table: &Table) -> String {
    let mut out = String::new();
    let mut sections: BTreeMap<&str, Vec<(&str, &Value)>> = BTreeMap::new();
    for (k, v) in &table.map {
        match k.rfind('.') {
            None => out.push_str(&format!("{k} = {}\n", write_value(v))),
            Some(dot) => sections
                .entry(&k[..dot])
                .or_default()
                .push((&k[dot + 1..], v)),
        }
    }
    for (section, entries) in sections {
        out.push_str(&format!("[{section}]\n"));
        for (k, v) in entries {
            out.push_str(&format!("{k} = {}\n", write_value(v)));
        }
    }
    out
}

fn write_value(v: &Value) -> String {
    write_value_at(v, false)
}

fn write_value_at(v: &Value, in_array: bool) -> String {
    match v {
        Value::Str(s) => {
            // the subset grammar has no escapes: degrade characters that
            // would break the quoting (or array splitting) to '_'
            let safe: String = s
                .chars()
                .map(|c| match c {
                    '"' | '\n' | '\r' => '_',
                    ',' | ']' if in_array => '_',
                    c => c,
                })
                .collect();
            format!("\"{safe}\"")
        }
        Value::Int(i) => format!("{i}"),
        Value::Float(f) => {
            if !f.is_finite() {
                "0.0".to_string()
            } else if f.fract() == 0.0 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => format!("{b}"),
        Value::Array(items) => {
            let body: Vec<String> = items.iter().map(|x| write_value_at(x, true)).collect();
            format!("[{}]", body.join(", "))
        }
    }
}

/// Parse a TOML-subset document. Errors carry the 1-based line number.
pub fn parse(input: &str) -> Result<Table, String> {
    let mut table = Table::default();
    let mut prefix = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            prefix = format!("{name}.");
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = format!("{prefix}{}", k.trim());
        let value = parse_value(v.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        table.map.insert(key, value);
    }
    Ok(table)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: no '#' inside our string values
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<Value, String> {
    if tok.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = tok.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = tok.strip_prefix('[') {
        let inner = body.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|t| parse_value(t.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    let clean = tok.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value: {tok}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let t = parse("a = 1\nb = \"x\"\nc = 2.5\nd = true\n").unwrap();
        assert_eq!(t.int_or("a", 0), 1);
        assert_eq!(t.str_or("b", ""), "x");
        assert!((t.float_or("c", 0.0) - 2.5).abs() < 1e-12);
        assert!(t.bool_or("d", false));
    }

    #[test]
    fn sections_flatten() {
        let t = parse("[nic]\ncache = 400\n[link.a]\nrate = 40\n").unwrap();
        assert_eq!(t.int_or("nic.cache", 0), 400);
        assert_eq!(t.int_or("link.a.rate", 0), 40);
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = parse("# header\n\na = 1  # trailing\n").unwrap();
        assert_eq!(t.int_or("a", 0), 1);
    }

    #[test]
    fn arrays() {
        let t = parse("sizes = [64, 4096, 65536]\n").unwrap();
        let arr = t.get("sizes").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_int(), Some(65536));
    }

    #[test]
    fn underscore_numbers() {
        let t = parse("n = 1_000_000\n").unwrap();
        assert_eq!(t.int_or("n", 0), 1_000_000);
    }

    #[test]
    fn error_lines_reported() {
        let err = parse("a = 1\nbogus line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(t.str_or("s", ""), "a#b");
    }

    #[test]
    fn writer_roundtrips_sample_config() {
        let t = parse(crate::config::SAMPLE).unwrap();
        let again = parse(&write(&t)).unwrap();
        assert_eq!(t, again, "parse→write→parse must be identity");
    }

    #[test]
    fn writer_preserves_value_types() {
        let mut t = Table::default();
        t.set("top", Value::Int(3));
        t.set("fabric.rate", Value::Float(40.0));
        t.set("fabric.name", Value::Str("tor".into()));
        t.set("fabric.lossless", Value::Bool(true));
        t.set("scenario.sizes", Value::Array(vec![Value::Int(64), Value::Int(4096)]));
        let doc = write(&t);
        let back = parse(&doc).unwrap();
        assert_eq!(back, t, "doc was:\n{doc}");
        // a whole float must re-parse as Float, not Int
        assert!(matches!(back.get("fabric.rate"), Some(Value::Float(_))));
    }

    #[test]
    fn writer_degrades_unrepresentable_values_but_stays_parseable() {
        let mut t = Table::default();
        t.set("s", Value::Str("a\"b\nc".into()));
        t.set("nan", Value::Float(f64::NAN));
        t.set("arr", Value::Array(vec![Value::Str("x,y]z".into())]));
        let back = parse(&write(&t)).expect("degraded output must still parse");
        assert_eq!(back.str_or("s", ""), "a_b_c");
        assert_eq!(back.get("nan"), Some(&Value::Float(0.0)));
        let arr = back.get("arr").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_str(), Some("x_y_z"));
    }
}
