//! Leveled logging to stderr with a global level set once from the CLI.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// High-level progress (the default).
    Info = 2,
    /// Detailed internal state.
    Debug = 3,
    /// Per-event firehose.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Set the global level from a CLI string (unknown → Info).
pub fn set_level_from_str(s: &str) {
    let level = match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    };
    set_level(level);
}

/// Would a message at `level` currently print?
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Print a message to stderr if `level` is enabled (macro backend).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

/// Log at Info level with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at Warn level with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at Debug level with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn from_str() {
        set_level_from_str("debug");
        assert!(enabled(Level::Debug));
        set_level_from_str("bogus");
        assert!(enabled(Level::Info) && !enabled(Level::Debug));
    }
}
