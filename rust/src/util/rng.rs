//! Deterministic PRNG (xoshiro256**) + distributions.
//!
//! Every stochastic component of the simulator takes an explicit seed so
//! whole experiments replay bit-identically (`DESIGN.md` §Determinism).

/// xoshiro256** by Blackman & Vigna — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; bound ≪ 2^64 here).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// true with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with mean `mean` (inter-arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG (stable: derived from the next state draw).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Zipf(θ) sampler over `{0..n-1}` using the rejection-inversion method of
/// Hörmann & Derflinger — O(1) per sample, used by the KV workload.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    /// Sampler over `{0..n-1}` with skew θ (θ≠1, θ>0).
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0 && (theta - 1.0).abs() > 1e-9);
        let h = |x: f64, t: f64| ((x).powf(1.0 - t)) / (1.0 - t);
        Zipf {
            n,
            theta,
            h_x1: h(1.5, theta) - 1.0,
            h_n: h(n as f64 + 0.5, theta),
            s: 2.0 - {
                // h^-1(h(2.5) - 2^-theta) ~ rejection constant
                let hx = h(2.5, theta) - (2f64).powf(-theta);
                ((1.0 - theta) * hx).powf(1.0 / (1.0 - theta))
            },
        }
    }

    /// Draw one key (head-skewed toward small values).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let h_inv = |x: f64| ((1.0 - self.theta) * x).powf(1.0 / (1.0 - self.theta));
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            let h = |y: f64| (y).powf(1.0 - self.theta) / (1.0 - self.theta);
            if k - x <= self.s || u >= h(k + 0.5) - (k).powf(-self.theta) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.gen_range(17);
            assert!(x < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exp(3.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zipf_skews_low_keys() {
        let z = Zipf::new(1000, 0.99);
        let mut r = Rng::new(5);
        let mut low = 0;
        let n = 50_000;
        for _ in 0..n {
            if z.sample(&mut r) < 100 {
                low += 1;
            }
        }
        // with theta=.99 the head is heavily favoured; >50% mass in top 10%
        assert!(low as f64 / n as f64 > 0.5, "low frac = {}", low as f64 / n as f64);
    }

    #[test]
    fn zipf_within_range() {
        let z = Zipf::new(50, 0.9);
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 50);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
