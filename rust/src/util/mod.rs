//! Home-grown substrates.
//!
//! The build environment has no crates.io access at all — the crate
//! compiles with zero external dependencies — so the usual ecosystem
//! crates (anyhow, clap, criterion, proptest, serde, toml, rand) are
//! unavailable. Per the reproduction's build-everything rule these modules
//! implement the required functionality from scratch; each is small,
//! tested, and used across the crate. `scripts/verify.sh` keeps the
//! zero-dependency property enforced.

pub mod error;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod tomlmini;
pub mod jsonmini;
pub mod logging;
