//! Home-grown substrates.
//!
//! The build environment has no crates.io access beyond the `xla` crate's
//! dependency closure, so the usual ecosystem crates (clap, criterion,
//! proptest, serde, rand) are unavailable. Per the reproduction's
//! build-everything rule these modules implement the required functionality
//! from scratch; each is small, tested, and used across the crate.

pub mod rng;
pub mod stats;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod tomlmini;
pub mod jsonmini;
pub mod logging;
