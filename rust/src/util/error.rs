//! Crate-wide error type (anyhow is unavailable offline).
//!
//! A deliberately small work-alike for the slice of `anyhow` this crate
//! used: a string-backed [`Error`] with an optional context chain, the
//! [`Context`] extension trait for decorating fallible calls, and the
//! crate-wide [`Result`] alias re-exported from `lib.rs`. `{e}` prints the
//! outermost message; `{e:#}` prints the whole chain (`a: b: c`), matching
//! the anyhow formatting the binaries already relied on.

use std::fmt;

/// A string-backed error with an optional chain of context messages.
#[derive(Clone, Debug)]
pub struct Error {
    /// Context chain, outermost first; always at least one entry.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, m: impl fmt::Display) -> Error {
        self.chain.insert(0, m.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias (re-exported as `rdmavisor::Result`).
pub type Result<T> = std::result::Result<T, Error>;

/// `anyhow::Context`-style extension for decorating fallible calls.
pub trait Context<T> {
    /// Wrap the error with a static context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    // `{e:#}` so an inner crate `Error` contributes its WHOLE chain (plain
    // `{}` would print only its outermost entry); for other error types
    // alternate display is normally identical to the default.
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::msg("root cause").context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: root cause");
    }

    #[test]
    fn context_trait_on_results() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let ok: std::result::Result<u32, String> = Ok(7);
        assert_eq!(ok.context("unused").unwrap(), 7);
    }

    #[test]
    fn nested_context_keeps_the_whole_chain() {
        // a crate Error re-wrapped through the trait must not lose its root
        let inner: Result<()> = Err(Error::msg("root").context("mid"));
        let e = inner.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn context_trait_on_options() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
    }
}
