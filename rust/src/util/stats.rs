//! Statistics: streaming summaries and HDR-style latency histograms.

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded in.
    pub fn count(&self) -> u64 { self.n }
    /// Running mean.
    pub fn mean(&self) -> f64 { self.mean }
    /// Smallest observation (0 when empty).
    pub fn min(&self) -> f64 { if self.n == 0 { 0.0 } else { self.min } }
    /// Largest observation (0 when empty).
    pub fn max(&self) -> f64 { if self.n == 0 { 0.0 } else { self.max } }

    /// Sample variance (Bessel-corrected).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 { self.variance().sqrt() }

    /// Relative standard error of the mean — bench convergence criterion.
    pub fn rel_stderr(&self) -> f64 {
        if self.n < 2 || self.mean == 0.0 { return f64::INFINITY; }
        (self.stddev() / (self.n as f64).sqrt()) / self.mean.abs()
    }
}

/// Log-bucketed histogram: 64 major (power-of-two) × `SUB` minor buckets,
/// ~1.6% relative error — an HdrHistogram work-alike for latency percentiles.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    total: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 5; // 32 sub-buckets per power of two
const SUB: usize = 1 << SUB_BITS;

impl Default for Histogram {
    fn default() -> Self { Self::new() }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram { buckets: vec![0; 64 * SUB], count: 0, total: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let exp = 63 - v.leading_zeros(); // floor(log2 v) >= SUB_BITS
        let sub = (v >> (exp - SUB_BITS)) as usize & (SUB - 1);
        ((exp - SUB_BITS + 1) as usize) * SUB + sub
    }

    /// Record one value.
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.total += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Values recorded.
    pub fn count(&self) -> u64 { self.count }
    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 { if self.count == 0 { 0 } else { self.min } }
    /// Largest recorded value.
    pub fn max(&self) -> u64 { self.max }

    /// Exact mean of all recorded values.
    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.total as f64 / self.count as f64 }
    }

    /// Approximate value at quantile `q ∈ [0,1]` (returns bucket lower bound).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 { return 0; }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::lower_bound(i);
            }
        }
        self.max
    }

    fn lower_bound(idx: usize) -> u64 {
        let major = idx / SUB;
        let minor = (idx % SUB) as u64;
        if major == 0 {
            return minor;
        }
        let exp = major as u32 + SUB_BITS - 1;
        (1u64 << exp) | (minor << (exp - SUB_BITS))
    }

    /// Median.
    pub fn p50(&self) -> u64 { self.quantile(0.50) }
    /// 90th percentile.
    pub fn p90(&self) -> u64 { self.quantile(0.90) }
    /// 99th percentile.
    pub fn p99(&self) -> u64 { self.quantile(0.99) }
    /// 99.9th percentile.
    pub fn p999(&self) -> u64 { self.quantile(0.999) }

    /// Fold another histogram's buckets into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-window throughput accumulator (events and bytes per window).
#[derive(Clone, Debug, Default)]
pub struct Throughput {
    /// Events accumulated.
    pub events: u64,
    /// Bytes accumulated.
    pub bytes: u64,
}

impl Throughput {
    /// Count one event of `bytes` bytes.
    pub fn add(&mut self, bytes: u64) {
        self.events += 1;
        self.bytes += bytes;
    }

    /// Gb/s given an elapsed time in nanoseconds.
    pub fn gbps(&self, elapsed_ns: u64) -> f64 {
        if elapsed_ns == 0 { return 0.0; }
        (self.bytes as f64 * 8.0) / elapsed_ns as f64
    }

    /// Million events per second.
    pub fn mops(&self, elapsed_ns: u64) -> f64 {
        if elapsed_ns == 0 { return 0.0; }
        self.events as f64 * 1e3 / elapsed_ns as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn histogram_exact_small_values() {
        let mut h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 31);
        assert_eq!(h.count(), 32);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let (p50, p90, p99) = (h.p50(), h.p90(), h.p99());
        assert!(p50 <= p90 && p90 <= p99);
        // ~2% relative error bound on the log buckets
        assert!((p50 as f64 - 50_000.0).abs() / 50_000.0 < 0.05, "p50={p50}");
        assert!((p99 as f64 - 99_000.0).abs() / 99_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn histogram_merge_matches_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 1..1000u64 {
            if v % 2 == 0 { a.record(v) } else { b.record(v) }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.p50(), c.p50());
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn throughput_math() {
        let mut t = Throughput::default();
        t.add(125_000_000); // 1 Gbit
        assert!((t.gbps(1_000_000_000) - 1.0).abs() < 1e-9);
        // 1 event in 1 µs = 1 M events/s
        assert!((t.mops(1_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn quantile_edges() {
        let mut h = Histogram::new();
        h.record(500);
        assert_eq!(h.quantile(0.0), h.quantile(1.0));
        assert_eq!(h.count(), 1);
    }
}
