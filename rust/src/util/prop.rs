//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! A property runs against `cases` random inputs drawn from a generator; on
//! failure the harness greedily *shrinks* the failing input via the
//! generator's `shrink` candidates and reports the minimal reproducer plus
//! the seed that regenerates it.

use super::rng::Rng;

/// A generator of values of type `T` with shrinking.
pub trait Gen<T> {
    /// Draw one random value.
    fn gen(&self, rng: &mut Rng) -> T;
    /// Candidate smaller values; default: no shrinking.
    fn shrink(&self, _value: &T) -> Vec<T> {
        Vec::new()
    }
}

/// Uniform u64 in [lo, hi].
pub struct U64Range(pub u64, pub u64);

impl Gen<u64> for U64Range {
    fn gen(&self, rng: &mut Rng) -> u64 {
        let span = self.1.wrapping_sub(self.0).wrapping_add(1);
        if span == 0 {
            // full-u64 range: every value is valid
            return rng.next_u64();
        }
        self.0 + rng.gen_range(span)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            let span = *v - self.0;
            // binary-style descent candidates: lo, then successive
            // fractions of the way back toward v, then v-1
            out.push(self.0);
            out.push(self.0 + span / 2);
            out.push(self.0 + span * 3 / 4);
            out.push(self.0 + span * 7 / 8);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform usize in [lo, hi].
pub struct UsizeRange(pub usize, pub usize);

impl Gen<usize> for UsizeRange {
    fn gen(&self, rng: &mut Rng) -> usize {
        U64Range(self.0 as u64, self.1 as u64).gen(rng) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        U64Range(self.0 as u64, self.1 as u64)
            .shrink(&(*v as u64))
            .into_iter()
            .map(|x| x as usize)
            .collect()
    }
}

/// Vec of T with length in [min_len, max_len].
pub struct VecGen<G> {
    /// Element generator.
    pub elem: G,
    /// Minimum generated length.
    pub min_len: usize,
    /// Maximum generated length.
    pub max_len: usize,
}

impl<T: Clone, G: Gen<T>> Gen<Vec<T>> for VecGen<G> {
    fn gen(&self, rng: &mut Rng) -> Vec<T> {
        let len = UsizeRange(self.min_len, self.max_len).gen(rng);
        (0..len).map(|_| self.elem.gen(rng)).collect()
    }
    fn shrink(&self, v: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // drop back half, drop one element
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut one_less = v.clone();
            one_less.pop();
            out.push(one_less);
        }
        // shrink each element (first few positions only to bound work)
        for i in 0..v.len().min(4) {
            for cand in self.elem.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out
    }
}

/// Result of a property run.
pub struct PropReport<T> {
    /// Cases executed before stopping.
    pub cases: usize,
    /// Minimal failing input + message + seed, if the property failed.
    pub failure: Option<(T, String, u64)>, // minimal input, message, seed
}

/// Run `prop` against `cases` random values from `gen`. Panics with the
/// minimal failing input (property-test style) unless `soft` reporting is
/// used via [`check_report`].
pub fn check<T, G, F>(seed: u64, cases: usize, gen: &G, prop: F)
where
    T: Clone + std::fmt::Debug,
    G: Gen<T>,
    F: Fn(&T) -> Result<(), String>,
{
    if let Some((min, msg, s)) = check_report(seed, cases, gen, &prop).failure {
        panic!("property failed (seed={s}): {msg}\nminimal input: {min:?}");
    }
}

/// Like [`check`] but returns the report instead of panicking.
pub fn check_report<T, G, F>(seed: u64, cases: usize, gen: &G, prop: &F) -> PropReport<T>
where
    T: Clone + std::fmt::Debug,
    G: Gen<T>,
    F: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min, msg) = shrink_loop(gen, prop, input, msg);
            return PropReport { cases: case + 1, failure: Some((min, msg, seed)) };
        }
    }
    PropReport { cases, failure: None }
}

fn shrink_loop<T, G, F>(gen: &G, prop: &F, mut cur: T, mut msg: String) -> (T, String)
where
    T: Clone + std::fmt::Debug,
    G: Gen<T>,
    F: Fn(&T) -> Result<(), String>,
{
    // Greedy descent, bounded to avoid pathological generators.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in gen.shrink(&cur) {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, &U64Range(0, 1000), |&x| {
            if x <= 1000 { Ok(()) } else { Err("out of range".into()) }
        });
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let report = check_report(2, 500, &U64Range(0, 1000), &|&x: &u64| {
            if x < 500 { Ok(()) } else { Err(format!("{x} >= 500")) }
        });
        let (min, _, _) = report.failure.expect("should fail");
        // greedy shrink should land on or near the boundary
        assert!(min >= 500 && min <= 520, "min={min}");
    }

    #[test]
    fn vec_gen_respects_len_bounds() {
        let g = VecGen { elem: U64Range(0, 9), min_len: 2, max_len: 5 };
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = g.gen(&mut rng);
            assert!((2..=5).contains(&v.len()));
            assert!(v.iter().all(|&x| x <= 9));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = check_report(7, 50, &U64Range(0, 100), &|&x: &u64| {
            if x != 73 { Ok(()) } else { Err("hit".into()) }
        });
        let r2 = check_report(7, 50, &U64Range(0, 100), &|&x: &u64| {
            if x != 73 { Ok(()) } else { Err("hit".into()) }
        });
        assert_eq!(r1.failure.is_some(), r2.failure.is_some());
    }
}
