//! Minimal JSON parser + writer (serde_json is unavailable offline).
//!
//! Full JSON value model with a recursive-descent parser; used to read
//! `artifacts/manifest.json` and to emit machine-readable results from the
//! figure harnesses. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64; written as an integer when whole).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Array element by index.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The numeric value truncated to u64, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{tok}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"seed":0,"variants":[{"name":"model_b1","batch":1,"input":{"shape":[1,64],"dtype":"i32"}}]}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(0));
        let var = v.get("variants").unwrap().idx(0).unwrap();
        assert_eq!(var.get("name").unwrap().as_str(), Some("model_b1"));
        assert_eq!(
            var.get("input").unwrap().get("shape").unwrap().idx(1).unwrap().as_u64(),
            Some(64)
        );
        // reserialize parses to the same value
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\ncA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\nc\u{41}"));
        let s = Json::Str("x\"y\n".into()).to_string();
        assert_eq!(parse(&s).unwrap().as_str(), Some("x\"y\n"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
