//! Minimal argv parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Typed getters parse on demand and report friendly errors.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positionals, `--key value` options
/// and bare `--flag`s.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-option token (when parsed with a subcommand).
    pub subcommand: Option<String>,
    /// Non-option tokens after the subcommand.
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    /// Parse, treating the first non-option token as the subcommand.
    pub fn parse_with_subcommand(argv: &[String]) -> Args {
        Self::parse_inner(argv, true)
    }

    /// Parse with no subcommand concept.
    pub fn parse(argv: &[String]) -> Args {
        Self::parse_inner(argv, false)
    }

    fn parse_inner(argv: &[String], want_sub: bool) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options
                        .entry(body.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else if want_sub && out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        out
    }

    /// Was `--name` given (as a flag or with a value)?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    /// Last value given for `--name`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every value given for `--name`, in order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// String value of `--name`, or `default`.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// u64 value of `--name`, or `default`; exits with a message on junk.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.parse_or(name, default)
    }

    /// usize value of `--name`, or `default`; exits with a message on junk.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.parse_or(name, default)
    }

    /// f64 value of `--name`, or `default`; exits with a message on junk.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.parse_or(name, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("warning: --{name}={s} not parseable; using default");
                std::process::exit(2)
            }),
        }
    }

    /// Parse a comma-separated list of integers, e.g. `--sizes 64,4096,65536`.
    pub fn u64_list(&self, name: &str, default: &[u64]) -> Vec<u64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| t.trim().parse().expect("bad integer list"))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // note the documented ambiguity rule: `--key tok` binds tok as the
        // value of key, so positionals go before flag-style options.
        let a = Args::parse_with_subcommand(&argv("bench out.csv --conns 100 --verbose"));
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.u64_or("conns", 1), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&argv("--size=4096 --name=x"));
        assert_eq!(a.u64_or("size", 0), 4096);
        assert_eq!(a.get("name"), Some("x"));
    }

    #[test]
    fn last_value_wins() {
        let a = Args::parse(&argv("--n 1 --n 2"));
        assert_eq!(a.u64_or("n", 0), 2);
        assert_eq!(a.get_all("n"), vec!["1", "2"]);
    }

    #[test]
    fn integer_list() {
        let a = Args::parse(&argv("--sizes 64,128,4096"));
        assert_eq!(a.u64_list("sizes", &[1]), vec![64, 128, 4096]);
        assert_eq!(a.u64_list("other", &[7]), vec![7]);
    }

    #[test]
    fn defaults_when_absent() {
        let a = Args::parse(&argv(""));
        assert_eq!(a.u64_or("x", 9), 9);
        assert_eq!(a.str_or("s", "d"), "d");
        assert!(!a.flag("v"));
    }

    #[test]
    fn trailing_flag_no_value() {
        let a = Args::parse(&argv("--verbose"));
        assert!(a.flag("verbose"));
    }
}
