//! Zero-dependency ordered parallel map for the sweep harnesses.
//!
//! Every figure/bench sweep point runs an **independent** `Sim` — no
//! shared state, same seed, same config — so the rows can be computed on
//! worker threads and merged back in index order without changing a
//! single output byte. [`map_indexed`] is that executor: it hands items
//! to `jobs` scoped threads off a shared atomic cursor, each worker
//! writes its result into the slot matching the item's index, and the
//! caller receives the results in the original order. With `jobs <= 1`
//! (or a single item) it degenerates to a plain in-order loop on the
//! calling thread — the exact serial code path, not a one-thread pool —
//! so `--jobs 1` is byte-for-byte the old runner by construction.
//!
//! Determinism argument: a sweep point's result is a pure function of
//! its config (the simulator takes no wall-clock, no global RNG, no
//! cross-`Sim` state), and the merge is by index, so the output of
//! `--jobs N` equals the output of `--jobs 1` for every N. The
//! `tests/determinism.rs` `*_parallel_matches_serial` cases gate this
//! byte-for-byte.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Resolve a `--jobs` request: `0` means "use every available core"
/// (`std::thread::available_parallelism`), anything else is taken as-is.
pub fn effective_jobs(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// The bench binaries' jobs knob: `RDMAVISOR_JOBS` (0 = all cores),
/// defaulting to 1 (serial) so recorded numbers stay comparable.
pub fn jobs_from_env() -> usize {
    std::env::var("RDMAVISOR_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(effective_jobs)
        .unwrap_or(1)
}

/// Run `f(index, item)` over every item, on up to `jobs` threads, and
/// return the results **in item order**. `jobs <= 1` runs the items
/// sequentially on the calling thread (the exact serial path). A panic
/// in any worker propagates to the caller once the scope joins.
pub fn map_indexed<I, T, F>(items: Vec<I>, jobs: usize, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(usize, I) -> T + Sync,
{
    let n = items.len();
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    // Work items and result slots are index-addressed: workers only ever
    // touch disjoint slots, the Mutexes exist to satisfy the borrow
    // checker across threads (they are uncontended by construction).
    let work: Vec<Mutex<Option<I>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i]
                    .lock()
                    .expect("work slot poisoned")
                    .take()
                    .expect("work item taken once");
                let r = f(i, item);
                *slots[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned")
                .expect("every index produced a result")
        })
        .collect()
}

/// One unit of pool work: `(slot index, owned item, closure to run on it)`.
type Job<T> = (usize, T, Box<dyn FnOnce(&mut T) + Send>);

/// A persistent pool of worker threads that take **ownership** of their
/// work items for the duration of a call — built for the sharded
/// simulator, whose barrier loop scatters the same `Shard` values to
/// workers thousands of times per run. `std::thread::scope` per window
/// would pay a spawn/join for every barrier; this pool spawns once and
/// afterwards a scatter costs two channel hops per item.
///
/// Ordering contract: [`OwnedPool::scatter`] reassembles results by index,
/// so the output order equals the input order no matter which worker ran
/// what or how the completions interleaved — the same merge-by-index
/// discipline [`map_indexed`] uses.
pub struct OwnedPool<T: Send + 'static> {
    txs: Vec<mpsc::Sender<Job<T>>>,
    done_rx: mpsc::Receiver<(usize, T)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl<T: Send + 'static> OwnedPool<T> {
    /// Spawn a pool of `workers.max(1)` threads. Threads idle on their
    /// job channels until [`OwnedPool::scatter`] feeds them and exit when
    /// the pool is dropped.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (done_tx, done_rx) = mpsc::channel::<(usize, T)>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel::<Job<T>>();
            let done = done_tx.clone();
            txs.push(tx);
            handles.push(std::thread::spawn(move || {
                while let Ok((idx, mut item, job)) = rx.recv() {
                    job(&mut item);
                    if done.send((idx, item)).is_err() {
                        break; // pool dropped mid-flight; nothing to return to
                    }
                }
            }));
        }
        OwnedPool { txs, done_rx, handles }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Hand every item to a worker (round-robin), run `f` on each, and
    /// return the items — mutated in place — **in input order**. Blocks
    /// until all items come back. Panics if a worker died (i.e. a prior
    /// `f` panicked), which propagates failure instead of hanging.
    pub fn scatter<F>(&mut self, items: Vec<T>, f: F) -> Vec<T>
    where
        F: Fn(&mut T) + Send + Clone + 'static,
    {
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let g = f.clone();
            self.txs[i % self.txs.len()]
                .send((i, item, Box::new(move |t: &mut T| g(t))))
                .expect("pool worker exited");
        }
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, item) = self.done_rx.recv().expect("pool worker panicked");
            slots[i] = Some(item);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every scattered index returns"))
            .collect()
    }
}

impl<T: Send + 'static> Drop for OwnedPool<T> {
    fn drop(&mut self) {
        self.txs.clear(); // hang up the job channels → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join(); // a worker that panicked already surfaced in scatter
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree_in_order() {
        let items: Vec<u64> = (0..57).collect();
        let serial = map_indexed(items.clone(), 1, |i, x| (i as u64) * 1000 + x * x);
        let par4 = map_indexed(items.clone(), 4, |i, x| (i as u64) * 1000 + x * x);
        let par_many = map_indexed(items, 32, |i, x| (i as u64) * 1000 + x * x);
        assert_eq!(serial, par4);
        assert_eq!(serial, par_many);
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = map_indexed(Vec::<u32>::new(), 8, |_, x| x);
        assert!(none.is_empty());
        let one = map_indexed(vec![7u32], 8, |i, x| x + i as u32);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn jobs_zero_resolves_to_cores() {
        assert!(effective_jobs(0) >= 1);
        assert_eq!(effective_jobs(3), 3);
    }

    #[test]
    fn index_matches_item_position() {
        let got = map_indexed(vec![10, 20, 30], 2, |i, x| (i, x));
        assert_eq!(got, vec![(0, 10), (1, 20), (2, 30)]);
    }

    #[test]
    fn owned_pool_preserves_order_and_state() {
        let mut pool: OwnedPool<Vec<u64>> = OwnedPool::new(3);
        assert_eq!(pool.workers(), 3);
        // items carry state across scatters: each round appends one value,
        // and results must come back in input order every time
        let mut items: Vec<Vec<u64>> = (0..8).map(|i| vec![i]).collect();
        for round in 0..50u64 {
            items = pool.scatter(items, move |v| {
                let tag = v[0] * 1000 + round;
                v.push(tag);
            });
            for (i, v) in items.iter().enumerate() {
                assert_eq!(v[0], i as u64, "order broken in round {round}");
                assert_eq!(*v.last().unwrap(), i as u64 * 1000 + round);
            }
        }
        assert_eq!(items[5].len(), 51);
    }

    #[test]
    fn owned_pool_single_worker_and_empty_scatter() {
        let mut pool: OwnedPool<u32> = OwnedPool::new(0); // clamps to 1
        assert_eq!(pool.workers(), 1);
        let out = pool.scatter(vec![1, 2, 3], |x| *x *= 10);
        assert_eq!(out, vec![10, 20, 30]);
        let none = pool.scatter(Vec::new(), |_| {});
        assert!(none.is_empty());
    }
}
