//! Zero-dependency ordered parallel map for the sweep harnesses.
//!
//! Every figure/bench sweep point runs an **independent** `Sim` — no
//! shared state, same seed, same config — so the rows can be computed on
//! worker threads and merged back in index order without changing a
//! single output byte. [`map_indexed`] is that executor: it hands items
//! to `jobs` scoped threads off a shared atomic cursor, each worker
//! writes its result into the slot matching the item's index, and the
//! caller receives the results in the original order. With `jobs <= 1`
//! (or a single item) it degenerates to a plain in-order loop on the
//! calling thread — the exact serial code path, not a one-thread pool —
//! so `--jobs 1` is byte-for-byte the old runner by construction.
//!
//! Determinism argument: a sweep point's result is a pure function of
//! its config (the simulator takes no wall-clock, no global RNG, no
//! cross-`Sim` state), and the merge is by index, so the output of
//! `--jobs N` equals the output of `--jobs 1` for every N. The
//! `tests/determinism.rs` `*_parallel_matches_serial` cases gate this
//! byte-for-byte.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a `--jobs` request: `0` means "use every available core"
/// (`std::thread::available_parallelism`), anything else is taken as-is.
pub fn effective_jobs(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// The bench binaries' jobs knob: `RDMAVISOR_JOBS` (0 = all cores),
/// defaulting to 1 (serial) so recorded numbers stay comparable.
pub fn jobs_from_env() -> usize {
    std::env::var("RDMAVISOR_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(effective_jobs)
        .unwrap_or(1)
}

/// Run `f(index, item)` over every item, on up to `jobs` threads, and
/// return the results **in item order**. `jobs <= 1` runs the items
/// sequentially on the calling thread (the exact serial path). A panic
/// in any worker propagates to the caller once the scope joins.
pub fn map_indexed<I, T, F>(items: Vec<I>, jobs: usize, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(usize, I) -> T + Sync,
{
    let n = items.len();
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    // Work items and result slots are index-addressed: workers only ever
    // touch disjoint slots, the Mutexes exist to satisfy the borrow
    // checker across threads (they are uncontended by construction).
    let work: Vec<Mutex<Option<I>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i]
                    .lock()
                    .expect("work slot poisoned")
                    .take()
                    .expect("work item taken once");
                let r = f(i, item);
                *slots[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned")
                .expect("every index produced a result")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree_in_order() {
        let items: Vec<u64> = (0..57).collect();
        let serial = map_indexed(items.clone(), 1, |i, x| (i as u64) * 1000 + x * x);
        let par4 = map_indexed(items.clone(), 4, |i, x| (i as u64) * 1000 + x * x);
        let par_many = map_indexed(items, 32, |i, x| (i as u64) * 1000 + x * x);
        assert_eq!(serial, par4);
        assert_eq!(serial, par_many);
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = map_indexed(Vec::<u32>::new(), 8, |_, x| x);
        assert!(none.is_empty());
        let one = map_indexed(vec![7u32], 8, |i, x| x + i as u32);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn jobs_zero_resolves_to_cores() {
        assert!(effective_jobs(0) >= 1);
        assert_eq!(effective_jobs(3), 3);
    }

    #[test]
    fn index_matches_item_position() {
        let got = map_indexed(vec![10, 20, 30], 2, |i, x| (i, x));
        assert_eq!(got, vec![(0, 10), (1, 20), (2, 30)]);
    }
}
