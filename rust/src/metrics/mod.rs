//! Result recording: series tables, TSV/markdown emit, rate meters.
//!
//! The figure harnesses collect [`Series`] tables and write them under
//! `results/` so EXPERIMENTS.md can cite stable artifacts.

use std::collections::BTreeMap;
use std::io::Write;

use crate::fabric::time::Ns;
use crate::util::jsonmini::{obj, Json};

/// A named table: one x column + named y series, row-major.
#[derive(Clone, Debug, Default)]
pub struct Series {
    /// Table name (also the TSV filename stem).
    pub name: String,
    /// Name of the x column.
    pub x_label: String,
    /// Names of the y series.
    pub y_labels: Vec<String>,
    /// Rows: (x, one value per y series).
    pub rows: Vec<(f64, Vec<f64>)>,
}

impl Series {
    /// Empty series table.
    pub fn new(name: &str, x_label: &str, y_labels: &[&str]) -> Series {
        Series {
            name: name.to_string(),
            x_label: x_label.to_string(),
            y_labels: y_labels.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; `ys` must match the series count.
    pub fn push(&mut self, x: f64, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.y_labels.len(), "row width mismatch");
        self.rows.push((x, ys));
    }

    /// Render as tab-separated values with a header row.
    pub fn to_tsv(&self) -> String {
        let mut s = format!("{}\t{}\n", self.x_label, self.y_labels.join("\t"));
        for (x, ys) in &self.rows {
            s.push_str(&format!(
                "{}\t{}\n",
                x,
                ys.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join("\t")
            ));
        }
        s
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("| {} | {} |\n", self.x_label, self.y_labels.join(" | "));
        s.push_str(&format!("|{}|\n", "---|".repeat(self.y_labels.len() + 1)));
        for (x, ys) in &self.rows {
            s.push_str(&format!(
                "| {} | {} |\n",
                x,
                ys.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(" | ")
            ));
        }
        s
    }

    /// Render as a JSON document: `{name, x, series, rows}` with NaN/inf
    /// degraded to `null` (strict-JSON safe). Keys are sorted and rows
    /// kept in insertion order, so equal series serialize byte-identically
    /// — the determinism tests compare exactly this string.
    pub fn to_json(&self) -> Json {
        let num = |f: f64| if f.is_finite() { Json::Num(f) } else { Json::Null };
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("x", Json::Str(self.x_label.clone())),
            (
                "series",
                Json::Arr(self.y_labels.iter().map(|l| Json::Str(l.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|(x, ys)| {
                            let mut row = vec![num(*x)];
                            row.extend(ys.iter().map(|y| num(*y)));
                            Json::Arr(row)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<dir>/<name>.tsv`; returns the path.
    pub fn write_tsv(&self, dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.tsv", self.name);
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_tsv().as_bytes())?;
        Ok(path)
    }

    /// The y value of series `label` at the row nearest to `x`.
    pub fn value_at(&self, label: &str, x: f64) -> Option<f64> {
        let col = self.y_labels.iter().position(|l| l == label)?;
        self.rows
            .iter()
            .min_by(|a, b| {
                (a.0 - x).abs().partial_cmp(&(b.0 - x).abs()).unwrap()
            })
            .map(|(_, ys)| ys[col])
    }
}

/// Windowed rate meter for live dashboards (used by the serving example).
#[derive(Clone, Debug)]
pub struct RateMeter {
    window: Ns,
    events: BTreeMap<u64, u64>, // bucket start ns -> count
    bucket: u64,
}

impl RateMeter {
    /// Meter over a sliding `window`, bucketed `buckets` ways.
    pub fn new(window: Ns, buckets: u64) -> RateMeter {
        RateMeter { window, events: BTreeMap::new(), bucket: (window.0 / buckets).max(1) }
    }

    /// Record one event at `now` and age out old buckets.
    pub fn tick(&mut self, now: Ns) {
        *self.events.entry(now.0 / self.bucket).or_insert(0) += 1;
        let cutoff = now.0.saturating_sub(self.window.0) / self.bucket;
        self.events = self.events.split_off(&cutoff);
    }

    /// Events/second over the window ending at `now`.
    pub fn rate(&self, now: Ns) -> f64 {
        let cutoff = now.0.saturating_sub(self.window.0) / self.bucket;
        let n: u64 = self.events.range(cutoff..).map(|(_, c)| c).sum();
        n as f64 * 1e9 / self.window.0 as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_tsv_and_markdown() {
        let mut s = Series::new("fig5", "conns", &["naive", "raas"]);
        s.push(100.0, vec![36.1, 38.2]);
        s.push(1000.0, vec![19.8, 38.0]);
        let tsv = s.to_tsv();
        assert!(tsv.starts_with("conns\tnaive\traas\n"));
        assert!(tsv.contains("1000\t"));
        let md = s.to_markdown();
        assert!(md.contains("| conns | naive | raas |"));
    }

    #[test]
    fn series_json_degrades_nan_to_null() {
        let mut s = Series::new("t", "x", &["a"]);
        s.push(1.0, vec![f64::NAN]);
        s.push(2.0, vec![0.5]);
        let j = s.to_json().to_string();
        assert!(j.contains("[1,null]"), "{j}");
        assert!(j.contains("[2,0.5]"), "{j}");
        assert!(j.starts_with("{\"name\":\"t\""), "{j}");
    }

    #[test]
    fn value_at_nearest() {
        let mut s = Series::new("t", "x", &["y"]);
        s.push(1.0, vec![10.0]);
        s.push(5.0, vec![50.0]);
        assert_eq!(s.value_at("y", 4.4), Some(50.0));
        assert_eq!(s.value_at("y", 0.0), Some(10.0));
        assert_eq!(s.value_at("nope", 1.0), None);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut s = Series::new("t", "x", &["a", "b"]);
        s.push(1.0, vec![1.0]);
    }

    #[test]
    fn rate_meter_windows() {
        let mut m = RateMeter::new(Ns(1_000_000), 10);
        for i in 0..100 {
            m.tick(Ns(i * 10_000));
        }
        let r = m.rate(Ns(1_000_000));
        assert!(r > 50_000.0, "rate={r}");
        // events age out
        let r_late = m.rate(Ns(10_000_000));
        assert!(r_late < r);
    }
}
