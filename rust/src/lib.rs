//! # rdmavisor — RDMA-as-a-Service, reproduced
//!
//! Reproduction of *"RDMAvisor: Toward Deploying Scalable and Simple RDMA as
//! a Service in Datacenters"* (Wang et al., Nanjing University / Huawei,
//! CS.DC 2018).
//!
//! The crate is organised in three tiers (see `DESIGN.md`):
//!
//! * [`fabric`] — a deterministic discrete-event **simulated RDMA fabric**
//!   (QPs, CQs, SRQs, memory regions, an RNIC with a finite QP-context
//!   cache, 40 GbE links, a switch). This substitutes for the paper's
//!   ConnectX-3 RoCE testbed, which we do not have.
//! * [`raas`] — the paper's contribution: the RDMAvisor daemon. Socket-like
//!   API, lock-free QP sharing via vQPNs, shared-memory rings + eventfd
//!   doorbells, Worker/Poller threads, adaptive transport selection,
//!   registered buffer pools, host-wide SRQ sharing, CPU/memory telemetry.
//! * [`baselines`] — the comparison systems of the evaluation: *naive* RDMA
//!   (one QP per connection) and FaRM-style *locked* QP sharing.
//!
//! Supporting tiers: [`runtime`] loads AOT-lowered model artifacts and
//! executes them (simulated offline — see its module docs) from the serving
//! example's hot path; [`apps`] are example applications written against
//! the RaaS API; [`workload`] and [`metrics`] generate traffic and account
//! results; [`figures`] regenerates every table/figure of the paper's
//! evaluation; [`util`] contains the substrates the offline environment
//! forced us to build ourselves (error type, CLI, bench harness, property
//! testing, config parsing, stats).
//!
//! The crate compiles with **zero external dependencies** — std only; see
//! `scripts/verify.sh` for the enforcement check.

#![warn(missing_docs)]

pub mod util;
pub mod fabric;
pub mod raas;
pub mod baselines;
pub mod runtime;
pub mod apps;
pub mod workload;
pub mod metrics;
pub mod config;
pub mod figures;

/// Crate-wide error type (see [`util::error`]).
pub use util::error::Error;
/// Crate-wide result type (see [`util::error`]).
pub type Result<T> = util::error::Result<T>;
