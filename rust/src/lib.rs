//! # rdmavisor — RDMA-as-a-Service, reproduced
//!
//! Reproduction of *"RDMAvisor: Toward Deploying Scalable and Simple RDMA as
//! a Service in Datacenters"* (Wang et al., Nanjing University / Huawei,
//! CS.DC 2018).
//!
//! The crate is organised in three tiers (see `DESIGN.md`):
//!
//! * [`fabric`] — a deterministic discrete-event **simulated RDMA fabric**
//!   (QPs, CQs, SRQs, memory regions, an RNIC with a finite QP-context
//!   cache, 40 GbE links, a switch). This substitutes for the paper's
//!   ConnectX-3 RoCE testbed, which we do not have.
//! * [`raas`] — the paper's contribution: the RDMAvisor daemon. Socket-like
//!   API, lock-free QP sharing via vQPNs, shared-memory rings + eventfd
//!   doorbells, Worker/Poller threads, adaptive transport selection,
//!   registered buffer pools, host-wide SRQ sharing, CPU/memory telemetry.
//! * [`baselines`] — the comparison systems of the evaluation: *naive* RDMA
//!   (one QP per connection) and FaRM-style *locked* QP sharing.
//!
//! Supporting tiers: [`runtime`] loads AOT-compiled JAX/Pallas artifacts via
//! PJRT and executes them from the serving example's hot path; [`apps`] are
//! example applications written against the RaaS API; [`workload`] and
//! [`metrics`] generate traffic and account results; [`figures`] regenerates
//! every table/figure of the paper's evaluation; [`util`] contains the
//! substrates the offline environment forced us to build ourselves (CLI,
//! bench harness, property testing, config parsing, stats).

pub mod util;
pub mod fabric;
pub mod raas;
pub mod baselines;
pub mod runtime;
pub mod apps;
pub mod workload;
pub mod metrics;
pub mod config;
pub mod figures;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
