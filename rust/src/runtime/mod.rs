//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them.
//!
//! `make artifacts` lowers the L2 serving model (python/compile) to **HLO
//! text** (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos; the text parser reassigns ids). This module loads every variant
//! listed in `artifacts/manifest.json`, compiles each once on the PJRT CPU
//! client, and serves execute calls from the coordinator's hot path —
//! Python never runs at request time.

pub mod manifest;
pub mod executor;

pub use executor::{Executor, ModelOutput};
pub use manifest::{Manifest, Variant};
