//! Model runtime: load AOT-lowered artifacts and execute them.
//!
//! `python/compile` lowers the serving model to HLO text plus an
//! `artifacts/manifest.json` describing each batch-size variant. In this
//! offline reproduction the [`executor`] *simulates* execution (PJRT and
//! the `xla` crate are unreachable here — see the module docs): it loads
//! the same manifest, honours the same shapes, and produces deterministic
//! logits, so the serving hot path, dynamic batcher and demos behave
//! identically with zero external dependencies. Python never runs at
//! request time.

pub mod manifest;
pub mod executor;

pub use executor::{Executor, ModelOutput};
pub use manifest::{Manifest, Variant};
