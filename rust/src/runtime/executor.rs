//! Simulated model executor: compile-once, execute-many, zero dependencies.
//!
//! Earlier revisions executed real AOT-compiled HLO through PJRT via the
//! `xla` crate. That crate (and its C++ runtime) is unreachable in this
//! offline environment, so the executor now *simulates* a forward pass: it
//! keeps the exact external contract (load a [`Manifest`], one "compiled"
//! program per variant, token-ids in, logits out) while deriving the logits
//! deterministically from the input tokens with a splitmix-style hash.
//! Same input ⇒ bit-identical logits, which is all the serving path,
//! batcher, and tests observe. When `artifacts/manifest.json` is absent a
//! built-in synthetic manifest is used so the demo/serve commands run on a
//! fresh checkout.

use std::collections::HashMap;

use crate::util::error::{Context, Error, Result};

use super::manifest::{Manifest, Variant};

/// Output of one forward pass.
#[derive(Clone, Debug)]
pub struct ModelOutput {
    /// Flattened logits `[batch * seq * vocab]`.
    pub logits: Vec<f32>,
    /// Rows in the batch (includes padding rows).
    pub batch: usize,
    /// Sequence length of the variant.
    pub seq: usize,
    /// Vocabulary size of the variant.
    pub vocab: usize,
}

impl ModelOutput {
    /// Argmax token at (row, pos) — what the serving example replies with.
    pub fn argmax(&self, row: usize, pos: usize) -> usize {
        let base = (row * self.seq + pos) * self.vocab;
        let slice = &self.logits[base..base + self.vocab];
        slice
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// SplitMix64 finalizer: the per-position mixing function of the simulated
/// model. Cheap, stateless, and avalanche-complete — every token of a row
/// perturbs every logit of that row.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Compile-once executor over all manifest variants.
pub struct Executor {
    /// Per-variant "compiled program": the variant shape plus a fixed weight
    /// seed derived at load time (stands in for the compiled executable).
    variants: HashMap<String, (Variant, u64)>,
    /// The manifest the variants were loaded from.
    pub manifest: Manifest,
    /// Forward passes executed since load.
    pub executions: u64,
}

impl Executor {
    /// Load every variant in `dir` (one-time startup cost). Falls back to a
    /// built-in synthetic manifest when `dir` has none, so a fresh checkout
    /// can still serve (`rdmavisor demo inference`).
    pub fn load(dir: &str) -> Result<Executor> {
        let manifest = Manifest::load_or_synthetic(dir);
        Self::from_manifest(manifest)
    }

    /// Load from `dir`, failing (rather than synthesizing) when the
    /// manifest is absent or malformed.
    pub fn load_strict(dir: &str) -> Result<Executor> {
        let manifest = Manifest::load(dir).map_err(Error::msg).context("load manifest")?;
        Self::from_manifest(manifest)
    }

    /// "Compile" every variant of an already-parsed manifest.
    pub fn from_manifest(manifest: Manifest) -> Result<Executor> {
        if manifest.variants.is_empty() {
            return Err(Error::msg("manifest has no variants"));
        }
        let mut variants = HashMap::new();
        for v in &manifest.variants {
            // weight seed: a stable function of the manifest seed and the
            // variant shape, fixed for the executor's lifetime
            let weights = mix(manifest.seed)
                ^ mix(v.batch as u64)
                ^ mix((v.seq as u64) << 16)
                ^ mix((v.vocab as u64) << 32);
            variants.insert(v.name.clone(), (v.clone(), weights));
        }
        Ok(Executor { variants, manifest, executions: 0 })
    }

    /// Name of the backing execution platform.
    pub fn platform(&self) -> String {
        "sim-cpu".to_string()
    }

    /// Sorted names of the loaded variants.
    pub fn variant_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.variants.keys().cloned().collect();
        v.sort();
        v
    }

    /// Execute variant `name` on `tokens` (row-major `[batch, seq]` i32).
    /// Short batches are padded with token 0; extra rows are ignored by the
    /// caller (the batcher slices real rows out of the output).
    pub fn run(&mut self, name: &str, tokens: &[i32]) -> Result<ModelOutput> {
        let (variant, weights) = self
            .variants
            .get(name)
            .with_context(|| format!("unknown variant {name}"))?;
        let (variant, weights) = (variant.clone(), *weights);
        let want = variant.batch * variant.seq;
        let mut input = tokens.to_vec();
        if input.len() > want {
            return Err(Error::msg(format!("batch overflow: {} > {}", input.len(), want)));
        }
        input.resize(want, 0);

        let mut logits = Vec::with_capacity(want * variant.vocab);
        for row in input.chunks_exact(variant.seq) {
            // row state: order-sensitive rolling hash of the row's tokens
            let mut state = weights;
            for (i, &t) in row.iter().enumerate() {
                state = mix(state ^ mix((t as u64) << 1) ^ (i as u64));
            }
            for pos in 0..variant.seq {
                let pos_state = mix(state ^ (pos as u64));
                for v in 0..variant.vocab {
                    // map the 64-bit hash to a finite logit in [-1, 1)
                    let h = mix(pos_state ^ ((v as u64) << 7));
                    let unit = (h >> 11) as f32 / (1u64 << 53) as f32;
                    logits.push(unit * 2.0 - 1.0);
                }
            }
        }
        self.executions += 1;
        Ok(ModelOutput {
            logits,
            batch: variant.batch,
            seq: variant.seq,
            vocab: variant.vocab,
        })
    }

    /// Pick the variant for `n` requests and run (dynamic batcher entry).
    pub fn run_batched(&mut self, tokens_rows: &[Vec<i32>]) -> Result<(String, ModelOutput)> {
        let n = tokens_rows.len();
        let name = self
            .manifest
            .variant_for_batch(n)
            .context("no variants loaded")?
            .name
            .clone();
        let seq = self.variants[&name].0.seq;
        let mut flat = Vec::with_capacity(n * seq);
        for row in tokens_rows {
            let mut r = row.clone();
            r.resize(seq, 0);
            flat.extend_from_slice(&r);
        }
        let out = self.run(&name, &flat)?;
        Ok((name, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exe() -> Executor {
        Executor::from_manifest(Manifest::synthetic()).unwrap()
    }

    #[test]
    fn synthetic_manifest_loads_and_runs() {
        let mut e = exe();
        assert!(!e.variant_names().is_empty());
        let name = e.variant_names()[0].clone();
        let v = e.manifest.by_name(&name).unwrap().clone();
        let tokens: Vec<i32> = (0..v.batch * v.seq).map(|i| (i % v.vocab) as i32).collect();
        let out = e.run(&name, &tokens).unwrap();
        assert_eq!(out.logits.len(), v.batch * v.seq * v.vocab);
        assert!(out.logits.iter().all(|x| x.is_finite()));
        assert_eq!(e.executions, 1);
    }

    #[test]
    fn deterministic_and_input_sensitive() {
        let mut e = exe();
        let name = e.variant_names()[0].clone();
        let v = e.manifest.by_name(&name).unwrap().clone();
        let a: Vec<i32> = (0..v.batch * v.seq).map(|i| (i % 17) as i32).collect();
        let mut b = a.clone();
        b[0] ^= 1;
        let ra1 = e.run(&name, &a).unwrap();
        let ra2 = e.run(&name, &a).unwrap();
        let rb = e.run(&name, &b).unwrap();
        assert_eq!(ra1.logits, ra2.logits, "same input, same logits");
        assert_ne!(ra1.logits, rb.logits, "different input, different logits");
    }

    #[test]
    fn identical_rows_get_identical_logits() {
        let mut e = exe();
        let seq = e.manifest.variants[0].seq;
        let rows = vec![vec![7i32; seq]; 2];
        let (_, out) = e.run_batched(&rows).unwrap();
        let row = out.seq * out.vocab;
        assert_eq!(out.logits[..row], out.logits[row..2 * row]);
    }

    #[test]
    fn batch_overflow_rejected() {
        let mut e = exe();
        let name = e.variant_names()[0].clone();
        let v = e.manifest.by_name(&name).unwrap().clone();
        let too_many = vec![0i32; v.batch * v.seq + 1];
        assert!(e.run(&name, &too_many).is_err());
        assert!(e.run("nope", &[]).is_err());
    }
}
