//! PJRT executor: compile-once, execute-many over the CPU client.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per model
//! variant; token-id inputs in, logits out.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, Variant};

/// Output of one forward pass.
#[derive(Clone, Debug)]
pub struct ModelOutput {
    /// Flattened logits [batch * seq * vocab].
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl ModelOutput {
    /// Argmax token at (row, pos) — what the serving example replies with.
    pub fn argmax(&self, row: usize, pos: usize) -> usize {
        let base = (row * self.seq + pos) * self.vocab;
        let slice = &self.logits[base..base + self.vocab];
        slice
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Compile-once executor over all manifest variants.
pub struct Executor {
    client: xla::PjRtClient,
    variants: HashMap<String, (Variant, xla::PjRtLoadedExecutable)>,
    pub manifest: Manifest,
    pub executions: u64,
}

impl Executor {
    /// Load + compile every artifact in `dir` (one-time startup cost).
    pub fn load(dir: &str) -> Result<Executor> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut variants = HashMap::new();
        for v in &manifest.variants {
            let path = format!("{dir}/{}", v.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {}", v.name))?;
            variants.insert(v.name.clone(), (v.clone(), exe));
        }
        Ok(Executor { client, variants, manifest, executions: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn variant_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.variants.keys().cloned().collect();
        v.sort();
        v
    }

    /// Execute variant `name` on `tokens` (row-major [batch, seq] i32).
    /// Short batches are padded with token 0; extra rows are ignored by the
    /// caller (the batcher slices real rows out of the output).
    pub fn run(&mut self, name: &str, tokens: &[i32]) -> Result<ModelOutput> {
        let (variant, exe) = self
            .variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown variant {name}"))?;
        let want = variant.batch * variant.seq;
        let mut input = tokens.to_vec();
        if input.len() > want {
            return Err(anyhow!("batch overflow: {} > {}", input.len(), want));
        }
        input.resize(want, 0);
        let lit = xla::Literal::vec1(&input)
            .reshape(&[variant.batch as i64, variant.seq as i64])
            .context("reshape input")?;
        let result = exe.execute::<xla::Literal>(&[lit]).context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1().context("untuple")?;
        let logits = out.to_vec::<f32>().context("logits to vec")?;
        self.executions += 1;
        Ok(ModelOutput {
            logits,
            batch: variant.batch,
            seq: variant.seq,
            vocab: variant.vocab,
        })
    }

    /// Pick the variant for `n` requests and run (dynamic batcher entry).
    pub fn run_batched(&mut self, tokens_rows: &[Vec<i32>]) -> Result<(String, ModelOutput)> {
        let n = tokens_rows.len();
        let name = self
            .manifest
            .variant_for_batch(n)
            .ok_or_else(|| anyhow!("no variants loaded"))?
            .name
            .clone();
        let seq = self.variants[&name].0.seq;
        let mut flat = Vec::with_capacity(n * seq);
        for row in tokens_rows {
            let mut r = row.clone();
            r.resize(seq, 0);
            flat.extend_from_slice(&r);
        }
        let out = self.run(&name, &flat)?;
        Ok((name, out))
    }
}
