//! `artifacts/manifest.json` — shapes/dtypes of each AOT model variant.

use crate::util::jsonmini::{parse, Json};

/// One compiled model variant (one batch size).
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    /// Variant name (e.g. `model_b8`).
    pub name: String,
    /// Artifact file name within the artifacts directory.
    pub file: String,
    /// Compiled batch size.
    pub batch: usize,
    /// Compiled sequence length.
    pub seq: usize,
    /// Vocabulary size of the logits.
    pub vocab: usize,
    /// Estimated FLOPs per forward pass.
    pub flops_fwd: u64,
    /// Attention-kernel VMEM estimate.
    pub vmem_attn_bytes: u64,
    /// MLP-kernel VMEM estimate.
    pub vmem_mlp_bytes: u64,
}

/// The artifact set: all compiled variants plus the generation seed.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Seed the artifacts were generated with.
    pub seed: u64,
    /// All compiled variants.
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Parse a manifest document.
    pub fn parse(doc: &str) -> Result<Manifest, String> {
        let v = parse(doc)?;
        let variants = v
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or("manifest missing 'variants'")?
            .iter()
            .map(parse_variant)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Manifest {
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            variants,
        })
    }

    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.json");
        let doc = std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&doc)
    }

    /// A built-in two-variant manifest (batch 1 and batch 8, seq 64,
    /// vocab 256) used when no artifacts are on disk, so the serving demo
    /// runs end-to-end on a fresh checkout.
    pub fn synthetic() -> Manifest {
        let mk = |name: &str, batch: usize| Variant {
            name: name.to_string(),
            file: format!("{name}.hlo.txt"),
            batch,
            seq: 64,
            vocab: 256,
            flops_fwd: 58_700_000 * batch as u64,
            vmem_attn_bytes: 100_000,
            vmem_mlp_bytes: 200_000,
        };
        Manifest { seed: 0, variants: vec![mk("model_b1", 1), mk("model_b8", 8)] }
    }

    /// [`Manifest::load`], falling back to [`Manifest::synthetic`] when the
    /// directory has no (or a malformed) manifest.
    pub fn load_or_synthetic(dir: &str) -> Manifest {
        Self::load(dir).unwrap_or_else(|_| Self::synthetic())
    }

    /// Smallest variant whose batch ≥ `n` (the dynamic batcher's pick),
    /// else the largest available.
    pub fn variant_for_batch(&self, n: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.batch >= n)
            .min_by_key(|v| v.batch)
            .or_else(|| self.variants.iter().max_by_key(|v| v.batch))
    }

    /// Variant by exact name.
    pub fn by_name(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }
}

fn parse_variant(v: &Json) -> Result<Variant, String> {
    let get_u = |k: &str| v.get(k).and_then(Json::as_u64).ok_or(format!("variant missing {k}"));
    Ok(Variant {
        name: v
            .get("name")
            .and_then(Json::as_str)
            .ok_or("variant missing name")?
            .to_string(),
        file: v
            .get("file")
            .and_then(Json::as_str)
            .ok_or("variant missing file")?
            .to_string(),
        batch: get_u("batch")? as usize,
        seq: get_u("seq")? as usize,
        vocab: get_u("vocab")? as usize,
        flops_fwd: get_u("flops_fwd")?,
        vmem_attn_bytes: get_u("vmem_attn_bytes").unwrap_or(0),
        vmem_mlp_bytes: get_u("vmem_mlp_bytes").unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "seed": 0, "dtype": "float32",
      "variants": [
        {"name":"model_b1","file":"model_b1.hlo.txt","batch":1,"seq":64,"vocab":256,
         "flops_fwd":58700000,"vmem_attn_bytes":100000,"vmem_mlp_bytes":200000,
         "input":{"shape":[1,64],"dtype":"i32"}},
        {"name":"model_b8","file":"model_b8.hlo.txt","batch":8,"seq":64,"vocab":256,
         "flops_fwd":469800000,"vmem_attn_bytes":100000,"vmem_mlp_bytes":200000,
         "input":{"shape":[8,64],"dtype":"i32"}}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.variants[0].name, "model_b1");
        assert_eq!(m.variants[1].batch, 8);
    }

    #[test]
    fn variant_selection_for_batching() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.variant_for_batch(1).unwrap().batch, 1);
        assert_eq!(m.variant_for_batch(2).unwrap().batch, 8);
        assert_eq!(m.variant_for_batch(8).unwrap().batch, 8);
        // over the largest: fall back to the largest (caller splits)
        assert_eq!(m.variant_for_batch(100).unwrap().batch, 8);
    }

    #[test]
    fn by_name() {
        let m = Manifest::parse(DOC).unwrap();
        assert!(m.by_name("model_b8").is_some());
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // when `make artifacts` has run, validate the real file end-to-end
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(!m.variants.is_empty());
            for v in &m.variants {
                assert!(std::path::Path::new(&format!("artifacts/{}", v.file)).exists());
                // VMEM estimates must fit a 16 MiB TPU core budget
                assert!(v.vmem_attn_bytes < 16 << 20);
                assert!(v.vmem_mlp_bytes < 16 << 20);
            }
        }
    }
}
