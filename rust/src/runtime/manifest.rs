//! `artifacts/manifest.json` — shapes/dtypes of each AOT model variant.

use crate::util::jsonmini::{parse, Json};

/// One compiled model variant (one batch size).
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    pub name: String,
    pub file: String,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub flops_fwd: u64,
    pub vmem_attn_bytes: u64,
    pub vmem_mlp_bytes: u64,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub seed: u64,
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn parse(doc: &str) -> Result<Manifest, String> {
        let v = parse(doc)?;
        let variants = v
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or("manifest missing 'variants'")?
            .iter()
            .map(parse_variant)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Manifest {
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            variants,
        })
    }

    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.json");
        let doc = std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&doc)
    }

    /// Smallest variant whose batch ≥ `n` (the dynamic batcher's pick),
    /// else the largest available.
    pub fn variant_for_batch(&self, n: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.batch >= n)
            .min_by_key(|v| v.batch)
            .or_else(|| self.variants.iter().max_by_key(|v| v.batch))
    }

    pub fn by_name(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }
}

fn parse_variant(v: &Json) -> Result<Variant, String> {
    let get_u = |k: &str| v.get(k).and_then(Json::as_u64).ok_or(format!("variant missing {k}"));
    Ok(Variant {
        name: v
            .get("name")
            .and_then(Json::as_str)
            .ok_or("variant missing name")?
            .to_string(),
        file: v
            .get("file")
            .and_then(Json::as_str)
            .ok_or("variant missing file")?
            .to_string(),
        batch: get_u("batch")? as usize,
        seq: get_u("seq")? as usize,
        vocab: get_u("vocab")? as usize,
        flops_fwd: get_u("flops_fwd")?,
        vmem_attn_bytes: get_u("vmem_attn_bytes").unwrap_or(0),
        vmem_mlp_bytes: get_u("vmem_mlp_bytes").unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "seed": 0, "dtype": "float32",
      "variants": [
        {"name":"model_b1","file":"model_b1.hlo.txt","batch":1,"seq":64,"vocab":256,
         "flops_fwd":58700000,"vmem_attn_bytes":100000,"vmem_mlp_bytes":200000,
         "input":{"shape":[1,64],"dtype":"i32"}},
        {"name":"model_b8","file":"model_b8.hlo.txt","batch":8,"seq":64,"vocab":256,
         "flops_fwd":469800000,"vmem_attn_bytes":100000,"vmem_mlp_bytes":200000,
         "input":{"shape":[8,64],"dtype":"i32"}}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.variants[0].name, "model_b1");
        assert_eq!(m.variants[1].batch, 8);
    }

    #[test]
    fn variant_selection_for_batching() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.variant_for_batch(1).unwrap().batch, 1);
        assert_eq!(m.variant_for_batch(2).unwrap().batch, 8);
        assert_eq!(m.variant_for_batch(8).unwrap().batch, 8);
        // over the largest: fall back to the largest (caller splits)
        assert_eq!(m.variant_for_batch(100).unwrap().batch, 8);
    }

    #[test]
    fn by_name() {
        let m = Manifest::parse(DOC).unwrap();
        assert!(m.by_name("model_b8").is_some());
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // when `make artifacts` has run, validate the real file end-to-end
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(!m.variants.is_empty());
            for v in &m.variants {
                assert!(std::path::Path::new(&format!("artifacts/{}", v.file)).exists());
                // VMEM estimates must fit a 16 MiB TPU core budget
                assert!(v.vmem_attn_bytes < 16 << 20);
                assert!(v.vmem_mlp_bytes < 16 << 20);
            }
        }
    }
}
