//! Experiment / cluster configuration: TOML-subset files → typed configs.
//!
//! `rdmavisor --config cluster.toml <subcommand>` lets every knob of the
//! fabric, daemon and scenarios be set from a file; CLI flags override.
//! See `examples/cluster.toml` (written by `rdmavisor init-config`).

use crate::fabric::nic::NicConfig;
use crate::fabric::sim::FabricConfig;
use crate::fabric::time::Ns;
use crate::fabric::topo::{CcMode, TopoConfig};
use crate::raas::daemon::DaemonConfig;
use crate::util::tomlmini::{parse, Table};
use crate::workload::scenarios::ScenarioCfg;

/// Top-level typed configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Simulated-fabric parameters.
    pub fabric: FabricConfig,
    /// RDMAvisor daemon tunables.
    pub daemon: DaemonConfig,
    /// Scenario-driver parameters (inherits `fabric`).
    pub scenario: ScenarioCfg,
}

/// Parse a config document; unknown keys are rejected to catch typos.
pub fn from_str(doc: &str) -> Result<Config, String> {
    let t = parse(doc)?;
    validate_keys(&t)?;
    let mut cfg = Config {
        fabric: FabricConfig::default(),
        daemon: DaemonConfig::default(),
        scenario: ScenarioCfg::default(),
    };
    apply(&t, &mut cfg);
    Ok(cfg)
}

/// Read and parse a config file (see [`from_str`]).
pub fn from_file(path: &str) -> Result<Config, String> {
    let doc = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    from_str(&doc)
}

const KNOWN_KEYS: &[&str] = &[
    "fabric.nodes",
    "fabric.cores_per_node",
    "fabric.link_gbps",
    "fabric.mtu",
    "fabric.switch_latency_ns",
    "fabric.shards",
    "fabric.sq_depth",
    "fabric.rq_depth",
    "fabric.max_outstanding",
    "nic.engine_frame_ns",
    "nic.engine_wqe_ns",
    "nic.doorbell_ns",
    "nic.icm_cache_entries",
    "nic.icm_miss_ns",
    "nic.cqe_delay_ns",
    "topo.hosts_per_tor",
    "topo.oversub",
    "topo.mode",
    "topo.hop_latency_ns",
    "topo.ecn_threshold_bytes",
    "topo.buffer_bytes",
    "topo.cc_alpha",
    "topo.cc_min_rate",
    "topo.cc_ai_frac",
    "topo.cc_recovery_ns",
    "topo.cc_cnp_gap_ns",
    "daemon.srq_capacity",
    "daemon.srq_watermark",
    "daemon.recv_slot_bytes",
    "daemon.batch_max",
    "daemon.service_threads",
    "scenario.conns",
    "scenario.apps",
    "scenario.msg_bytes",
    "scenario.window",
    "scenario.duration_ms",
    "scenario.seed",
];

fn validate_keys(t: &Table) -> Result<(), String> {
    for k in t.keys() {
        if !KNOWN_KEYS.contains(&k.as_str()) {
            return Err(format!("unknown config key: {k}"));
        }
    }
    Ok(())
}

fn apply(t: &Table, cfg: &mut Config) {
    let f = &mut cfg.fabric;
    f.nodes = t.int_or("fabric.nodes", f.nodes as i64) as usize;
    f.cores_per_node = t.int_or("fabric.cores_per_node", f.cores_per_node as i64) as u32;
    f.link_gbps = t.float_or("fabric.link_gbps", f.link_gbps);
    f.mtu = t.int_or("fabric.mtu", f.mtu as i64) as u64;
    f.switch_latency_ns = t.int_or("fabric.switch_latency_ns", f.switch_latency_ns as i64) as u64;
    f.shards = t.int_or("fabric.shards", f.shards as i64) as usize;
    f.sq_depth = t.int_or("fabric.sq_depth", f.sq_depth as i64) as usize;
    f.rq_depth = t.int_or("fabric.rq_depth", f.rq_depth as i64) as usize;
    f.max_outstanding = t.int_or("fabric.max_outstanding", f.max_outstanding as i64) as usize;

    let n: &mut NicConfig = &mut f.nic;
    n.engine_frame_ns = t.int_or("nic.engine_frame_ns", n.engine_frame_ns as i64) as u64;
    n.engine_wqe_ns = t.int_or("nic.engine_wqe_ns", n.engine_wqe_ns as i64) as u64;
    n.doorbell_ns = t.int_or("nic.doorbell_ns", n.doorbell_ns as i64) as u64;
    n.icm_cache_entries = t.int_or("nic.icm_cache_entries", n.icm_cache_entries as i64) as usize;
    n.icm_miss_ns = t.int_or("nic.icm_miss_ns", n.icm_miss_ns as i64) as u64;
    n.cqe_delay_ns = t.int_or("nic.cqe_delay_ns", n.cqe_delay_ns as i64) as u64;

    // Any `topo.*` key switches the fabric from the single non-blocking
    // switch to the multi-switch Clos topology of DESIGN.md §14.
    if t.keys().any(|k| k.starts_with("topo.")) {
        let mut tc = TopoConfig::default();
        tc.hosts_per_tor = t.int_or("topo.hosts_per_tor", tc.hosts_per_tor as i64) as usize;
        tc.oversub = t.int_or("topo.oversub", tc.oversub as i64) as u32;
        tc.mode = match t.str_or("topo.mode", "dcqcn").as_str() {
            "nocc" => CcMode::NoCc,
            "pfc" => CcMode::Pfc,
            _ => CcMode::Dcqcn,
        };
        tc.hop_latency_ns = t.int_or("topo.hop_latency_ns", tc.hop_latency_ns as i64) as u64;
        tc.ecn_threshold_bytes =
            t.int_or("topo.ecn_threshold_bytes", tc.ecn_threshold_bytes as i64) as u64;
        tc.buffer_bytes = t.int_or("topo.buffer_bytes", tc.buffer_bytes as i64) as u64;
        tc.cc_alpha = t.float_or("topo.cc_alpha", tc.cc_alpha);
        tc.cc_min_rate = t.float_or("topo.cc_min_rate", tc.cc_min_rate);
        tc.cc_ai_frac = t.float_or("topo.cc_ai_frac", tc.cc_ai_frac);
        tc.cc_recovery_ns = t.int_or("topo.cc_recovery_ns", tc.cc_recovery_ns as i64) as u64;
        tc.cc_cnp_gap_ns = t.int_or("topo.cc_cnp_gap_ns", tc.cc_cnp_gap_ns as i64) as u64;
        f.topo = Some(tc);
    }

    let d = &mut cfg.daemon;
    d.srq_capacity = t.int_or("daemon.srq_capacity", d.srq_capacity as i64) as usize;
    d.srq_watermark = t.int_or("daemon.srq_watermark", d.srq_watermark as i64) as usize;
    d.recv_slot_bytes = t.int_or("daemon.recv_slot_bytes", d.recv_slot_bytes as i64) as u64;
    d.batch_max = t.int_or("daemon.batch_max", d.batch_max as i64) as usize;
    d.service_threads = t.int_or("daemon.service_threads", d.service_threads as i64) as u32;

    let s = &mut cfg.scenario;
    s.conns = t.int_or("scenario.conns", s.conns as i64) as usize;
    s.apps = t.int_or("scenario.apps", s.apps as i64) as u32;
    s.msg_bytes = t.int_or("scenario.msg_bytes", s.msg_bytes as i64) as u64;
    s.window = t.int_or("scenario.window", s.window as i64) as u32;
    s.duration = Ns::from_ms(t.int_or("scenario.duration_ms", 20) as u64);
    s.seed = t.int_or("scenario.seed", s.seed as i64) as u64;
    s.fabric = cfg.fabric.clone();
}

/// A documented sample config (written by `rdmavisor init-config`).
pub const SAMPLE: &str = r#"# rdmavisor cluster + experiment configuration
[fabric]
nodes = 4               # paper testbed: 4 machines
cores_per_node = 24     # 4x Xeon, 24 cores total
link_gbps = 40.0        # 40 Gb ConnectX-3 RoCE
mtu = 4096
switch_latency_ns = 1000
shards = 1              # parallel simulator partitions (0 = all cores)

[nic]
icm_cache_entries = 400 # QP-context cache capacity (Fig 5's knee)
icm_miss_ns = 2500      # PCIe fetch + writeback pipeline stall

# Uncomment to replace the single non-blocking switch with the fig-13
# fat-tree/Clos fabric (ToR + spine, finite buffers, ECN/DCQCN).
# [topo]
# hosts_per_tor = 8
# oversub = 4             # uplinks = hosts_per_tor / oversub
# mode = "dcqcn"          # dcqcn | nocc | pfc
# ecn_threshold_bytes = 65536
# buffer_bytes = 262144

[daemon]
srq_capacity = 4096
batch_max = 32
service_threads = 2

[scenario]
conns = 1000
msg_bytes = 65536
window = 1
duration_ms = 20
seed = 42
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_parses() {
        let cfg = from_str(SAMPLE).unwrap();
        assert_eq!(cfg.fabric.nodes, 4);
        assert_eq!(cfg.fabric.nic.icm_cache_entries, 400);
        assert_eq!(cfg.scenario.conns, 1000);
        assert_eq!(cfg.scenario.duration.0, 20_000_000);
    }

    #[test]
    fn defaults_survive_partial_config() {
        let cfg = from_str("[scenario]\nconns = 7\n").unwrap();
        assert_eq!(cfg.scenario.conns, 7);
        assert_eq!(cfg.fabric.link_gbps, 40.0);
        assert_eq!(cfg.daemon.batch_max, 32);
    }

    #[test]
    fn unknown_keys_rejected() {
        let err = from_str("[fabric]\nbogus = 1\n").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn shards_key_parses_and_inherits() {
        let cfg = from_str("[fabric]\nshards = 4\n").unwrap();
        assert_eq!(cfg.fabric.shards, 4);
        assert_eq!(cfg.scenario.fabric.shards, 4);
    }

    #[test]
    fn scenario_inherits_fabric() {
        let cfg = from_str("[fabric]\nlink_gbps = 100.0\n").unwrap();
        assert_eq!(cfg.scenario.fabric.link_gbps, 100.0);
    }

    #[test]
    fn topo_keys_install_clos() {
        let cfg = from_str("[topo]\nhosts_per_tor = 4\noversub = 2\nmode = \"pfc\"\n").unwrap();
        let tc = cfg.fabric.topo.expect("topo section installs Clos");
        assert_eq!(tc.hosts_per_tor, 4);
        assert_eq!(tc.oversub, 2);
        assert_eq!(tc.mode, CcMode::Pfc);
        assert_eq!(tc.uplinks(), 2);
        // the scenario fabric inherits the topology too
        assert!(cfg.scenario.fabric.topo.is_some());
    }

    #[test]
    fn no_topo_section_keeps_single_switch() {
        let cfg = from_str(SAMPLE).unwrap();
        assert!(cfg.fabric.topo.is_none());
    }
}
