//! A HERD-style key-value store over the RaaS API.
//!
//! The server materializes its value table inside its daemon's registered
//! pool; clients GET with one-sided READs at `slot(key)` (zero server CPU —
//! the RDMA pattern from [11]) and PUT with adaptive `send` (small values
//! ride SEND, large ride WRITE-with-imm; the server's Poller applies them).

use crate::fabric::sim::Sim;
use crate::raas::api::{Flags, RaasError};
use crate::raas::daemon::{Daemon, Delivery};
use crate::raas::transport::HostLoad;
use crate::raas::vqpn::Vqpn;
use crate::util::rng::{Rng, Zipf};

/// Fixed-slot value table layout (power-of-two slots over the pool).
#[derive(Clone, Copy, Debug)]
pub struct KvLayout {
    /// Number of fixed-size value slots.
    pub slots: u64,
    /// Bytes per slot.
    pub slot_bytes: u64,
}

impl KvLayout {
    /// Pool offset of `key`'s slot.
    pub fn offset(&self, key: u64) -> u64 {
        (key % self.slots) * self.slot_bytes
    }
}

/// Server-side state: owns the layout + applies PUTs from deliveries.
pub struct KvServer {
    /// Server app session id on its daemon.
    pub app: u32,
    /// Value-table layout served from the registered pool.
    pub layout: KvLayout,
    /// PUT messages applied to the table.
    pub puts_applied: u64,
}

impl KvServer {
    /// Register the server app and start listening on `port`.
    pub fn new(daemon: &mut Daemon, port: u16, layout: KvLayout) -> KvServer {
        let app = daemon.register_app();
        daemon.listen(app, port);
        KvServer { app, layout, puts_applied: 0 }
    }

    /// Drain deliveries (PUT messages); GETs never reach the CPU.
    pub fn service(&mut self, sim: &mut Sim, daemon: &mut Daemon) {
        while let Some(d) = daemon.recv_zero_copy(sim, self.app) {
            if let Delivery::Message { .. } = d {
                self.puts_applied += 1;
            }
        }
        // accept any pending connections
        while daemon.accept(self.app, 0).is_some() {}
    }
}

/// Client-side handle: zipf-keyed GET/PUT issue + completion counting.
pub struct KvClient {
    /// Client app session id on its daemon.
    pub app: u32,
    /// Logical connection to the server.
    pub conn: Vqpn,
    /// Server table layout (for GET offset math).
    pub layout: KvLayout,
    keys: Zipf,
    rng: Rng,
    /// GETs issued so far.
    pub gets_issued: u64,
    /// PUTs issued so far.
    pub puts_issued: u64,
    /// Completed ops observed by [`KvClient::drain`].
    pub gets_done: u64,
}

impl KvClient {
    /// Create a client over an open connection with a Zipf(θ) key stream.
    pub fn new(app: u32, conn: Vqpn, layout: KvLayout, seed: u64, theta: f64) -> KvClient {
        KvClient {
            app,
            conn,
            layout,
            keys: Zipf::new(layout.slots, theta),
            rng: Rng::new(seed),
            gets_issued: 0,
            puts_issued: 0,
            gets_done: 0,
        }
    }

    /// GET: one-sided READ of the key's slot.
    pub fn get(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        let key = self.keys.sample(&mut self.rng);
        let off = self.layout.offset(key);
        daemon.read(sim, self.conn, self.layout.slot_bytes, off, key)?;
        self.gets_issued += 1;
        Ok(())
    }

    /// PUT: adaptive send of a value (SEND small / WRITE-with-imm large).
    pub fn put(
        &mut self,
        sim: &mut Sim,
        daemon: &mut Daemon,
        value_bytes: u64,
    ) -> Result<(), RaasError> {
        daemon.send(sim, self.conn, value_bytes, Flags::default(), 0, HostLoad::default())?;
        self.puts_issued += 1;
        Ok(())
    }

    /// Count finished ops from the app inbox (GET reads and PUT sends both
    /// complete as `OpComplete`); returns how many completed.
    pub fn drain(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> u64 {
        let mut done = 0;
        while let Some(d) = daemon.recv_zero_copy(sim, self.app) {
            if let Delivery::OpComplete { ok: true, .. } = d {
                done += 1;
            }
        }
        self.gets_done += done;
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;
    use crate::fabric::types::NodeId;
    use crate::raas::daemon::{connect_via, DaemonConfig};

    fn setup() -> (Sim, Vec<Daemon>) {
        let mut sim = Sim::new(FabricConfig::default());
        let daemons = (0..2)
            .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
            .collect();
        (sim, daemons)
    }

    #[test]
    fn get_put_round_trip() {
        let (mut sim, mut daemons) = setup();
        let layout = KvLayout { slots: 1024, slot_bytes: 1024 };
        let mut server = KvServer::new(&mut daemons[1], 6000, layout);
        let capp = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 6000).unwrap();
        let mut client = KvClient::new(capp, conn, layout, 7, 0.99);

        for _ in 0..16 {
            client.get(&mut sim, &mut daemons[0]).unwrap();
        }
        client.put(&mut sim, &mut daemons[0], 512).unwrap();

        // drive to quiescence
        for _ in 0..200_000 {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            if sim.step().is_none() {
                for d in daemons.iter_mut() {
                    d.pump(&mut sim);
                }
                if sim.pending_events() == 0 {
                    break;
                }
            }
        }
        client.drain(&mut sim, &mut daemons[0]);
        server.service(&mut sim, &mut daemons[1]);
        // 16 GET completions + 1 PUT send-completion
        assert_eq!(client.gets_done, 17, "all ops complete");
        assert_eq!(server.puts_applied, 1, "PUT delivered to server");
    }

    #[test]
    fn layout_offsets_in_bounds() {
        let l = KvLayout { slots: 64, slot_bytes: 4096 };
        for k in 0..1000u64 {
            let off = l.offset(k);
            assert!(off + l.slot_bytes <= l.slots * l.slot_bytes);
            assert_eq!(off % l.slot_bytes, 0);
        }
    }
}
