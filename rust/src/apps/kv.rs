//! A HERD-style key-value store over the RaaS API — the remote-data-
//! structure tier the one-sided window data plane exists for (fig 11).
//!
//! The server materializes a fixed-slot value table inside its daemon's
//! registered pool. Two access modes, the figure's ablation axis:
//!
//! * **One-sided** ([`KvMode::OneSided`]): the client registers a remote
//!   window over the whole table once, then GETs with
//!   [`crate::raas::daemon::Daemon::window_read`] (one RTT, zero server
//!   CPU — the Storm repeat-get pattern) and PUTs with doorbell-coalesced
//!   [`crate::raas::daemon::Daemon::window_write`] bursts (RDMAbox
//!   request merging: N writes, one doorbell, one CQE). The server is
//!   fully passive on the data path.
//! * **RPC** ([`KvMode::Rpc`]): GET is a 48-byte SEND request the server
//!   answers with a value-sized SEND (two wire legs + server CPU per
//!   GET); PUT is an adaptive `send` of the value the server's Poller
//!   applies. This is the SEND/RECV baseline the paper's daemon already
//!   had.
//!
//! Keys are Zipfian ([`Zipf`]), values span the buffer classes
//! (64 B–128 KB, hashed per key), so the popular head stays hot while the
//! tail exercises every pool class.

use std::collections::VecDeque;

use crate::fabric::sim::Sim;
use crate::raas::api::{Flags, RaasError};
use crate::raas::daemon::{Daemon, Delivery, WindowToken};
use crate::raas::transport::HostLoad;
use crate::raas::vqpn::Vqpn;
use crate::util::rng::{Rng, Zipf};

/// Value-size classes a key's value is hashed into (64 B hot counters up
/// to 128 KB blobs — one per pool buffer class worth exercising).
pub const VALUE_CLASSES: &[u64] = &[64, 1 << 10, 16 << 10, 128 << 10];

/// Wire size of an RPC GET request (key + header). Deliberately below
/// the smallest value class so the server can tell requests from PUT
/// payloads by length (the simulator carries extents, not bytes).
pub const GET_REQ_BYTES: u64 = 48;

/// Fixed-slot value table layout (the server's pool-resident table).
#[derive(Clone, Copy, Debug)]
pub struct KvLayout {
    /// Number of fixed-size value slots.
    pub slots: u64,
    /// Bytes per slot (also the window's max-op bound). Must exceed
    /// [`GET_REQ_BYTES`] so RPC requests stay distinguishable.
    pub slot_bytes: u64,
}

impl KvLayout {
    /// Pool offset of `key`'s slot.
    pub fn offset(&self, key: u64) -> u64 {
        (key % self.slots) * self.slot_bytes
    }

    /// Total table span in bytes (the window registration span).
    pub fn bytes(&self) -> u64 {
        self.slots * self.slot_bytes
    }

    /// The value size stored under `key`: a per-key hash picks one of
    /// [`VALUE_CLASSES`], capped at the slot size.
    pub fn value_len(&self, key: u64) -> u64 {
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        VALUE_CLASSES[(h >> 61) as usize % VALUE_CLASSES.len()].min(self.slot_bytes)
    }
}

/// GET/PUT access mode — fig 11's ablation axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMode {
    /// One-sided READ gets + doorbell-coalesced WRITE puts through a
    /// registered window.
    OneSided,
    /// SEND-RPC gets (request + reply) + adaptive-send puts.
    Rpc,
}

/// Server-side state: owns the layout, applies PUTs, answers RPC GETs.
pub struct KvServer {
    /// Server app session id on its daemon.
    pub app: u32,
    /// Value-table layout served from the registered pool.
    pub layout: KvLayout,
    /// Access mode this server expects from its clients.
    pub mode: KvMode,
    /// PUT values applied to the table.
    pub puts_applied: u64,
    /// RPC GET requests answered with a value reply.
    pub gets_served: u64,
    /// Reply value sizes: the simulator carries extents, not bytes, so
    /// the requested key cannot ride the wire — replies draw from the
    /// server's own Zipf stream, the same popularity-weighted class mix
    /// the clients request (statistically equivalent, deterministic).
    keys: Zipf,
    rng: Rng,
    /// GETs accepted but not yet answered (send backpressure defers the
    /// reply to the next service turn instead of stalling the client
    /// forever).
    reply_queue: VecDeque<Vqpn>,
    port: u16,
}

impl KvServer {
    /// Register the server app and start listening on `port`.
    pub fn new(daemon: &mut Daemon, port: u16, layout: KvLayout, mode: KvMode, seed: u64) -> KvServer {
        let app = daemon.register_app();
        daemon.listen(app, port);
        KvServer {
            app,
            layout,
            mode,
            puts_applied: 0,
            gets_served: 0,
            keys: Zipf::new(layout.slots, 0.99),
            rng: Rng::new(seed),
            reply_queue: VecDeque::new(),
            port,
        }
    }

    /// One server turn: drain deliveries (PUT values, RPC GET requests),
    /// answer queued GETs, accept pending connections. In one-sided mode
    /// the data path never lands here — GETs read and PUTs write the
    /// table memory directly.
    pub fn service(&mut self, sim: &mut Sim, daemon: &mut Daemon) {
        while let Some(d) = daemon.recv_zero_copy(sim, self.app) {
            match d {
                Delivery::Message { conn, len, .. } => {
                    if self.mode == KvMode::Rpc && len == GET_REQ_BYTES {
                        self.reply_queue.push_back(conn);
                    } else {
                        self.puts_applied += 1;
                    }
                }
                // our own reply sends completing — nothing to do
                Delivery::OpComplete { .. } => {}
            }
        }
        while let Some(&conn) = self.reply_queue.front() {
            let key = self.keys.sample(&mut self.rng);
            let len = self.layout.value_len(key);
            match daemon.send(sim, conn, len, Flags::default(), key, HostLoad::default()) {
                Ok(_) => {
                    self.reply_queue.pop_front();
                    self.gets_served += 1;
                }
                // backpressure (pool/SQ exhausted): retry next turn
                Err(_) => break,
            }
        }
        while daemon.accept(self.app, self.port).is_some() {}
    }
}

/// Closed-loop client: one logical op in flight (a GET, or a PUT burst),
/// re-issued by the driver when [`KvClient::on_delivery`] reports the
/// round drained.
pub struct KvClient {
    /// Client app session id on its daemon.
    pub app: u32,
    /// Logical connection to the server.
    pub conn: Vqpn,
    /// Server table layout (offset + value-size math).
    pub layout: KvLayout,
    /// Access mode (must match the server's).
    pub mode: KvMode,
    /// Percent of issued ops that are GETs (95 = read-mostly, 50 =
    /// write-heavy).
    pub read_pct: u32,
    /// WRITEs per PUT round — the doorbell-coalescing group size in
    /// one-sided mode (every burst flushes as one group).
    pub put_burst: u32,
    /// GET ops issued.
    pub gets_issued: u64,
    /// PUT values issued.
    pub puts_issued: u64,
    /// Logical rounds fully completed (the app-level ops fig 11 counts).
    pub ops_done: u64,
    keys: Zipf,
    rng: Rng,
    /// The registered remote window (one-sided mode, set by `register`).
    window: Option<WindowToken>,
    /// Local completions outstanding for the current round.
    pending_ops: u32,
    /// Server reply Messages outstanding (RPC GETs only).
    awaiting_reply: u32,
}

impl KvClient {
    /// Create a client over an open connection with a Zipf(θ) key stream.
    pub fn new(
        app: u32,
        conn: Vqpn,
        layout: KvLayout,
        seed: u64,
        theta: f64,
        mode: KvMode,
        read_pct: u32,
        put_burst: u32,
    ) -> KvClient {
        KvClient {
            app,
            conn,
            layout,
            mode,
            read_pct: read_pct.min(100),
            put_burst: put_burst.max(1),
            gets_issued: 0,
            puts_issued: 0,
            ops_done: 0,
            keys: Zipf::new(layout.slots, theta),
            rng: Rng::new(seed),
            window: None,
            pending_ops: 0,
            awaiting_reply: 0,
        }
    }

    /// One-sided setup: register the remote window over the whole table
    /// (one standing lease; every later GET/PUT skips the per-op lease
    /// path). No-op in RPC mode.
    pub fn register(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        if self.mode == KvMode::OneSided && self.window.is_none() {
            self.window =
                Some(daemon.register_window(sim, self.conn, 0, self.layout.bytes(), self.layout.slot_bytes)?);
        }
        Ok(())
    }

    /// GET the value under the next Zipf key.
    pub fn get(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        let key = self.keys.sample(&mut self.rng);
        let len = self.layout.value_len(key);
        match self.mode {
            KvMode::OneSided => {
                let win = self.window.ok_or(RaasError::StaleWindow)?;
                daemon.window_read(sim, win, len, self.layout.offset(key), key)?;
                self.pending_ops += 1;
            }
            KvMode::Rpc => {
                daemon.send(sim, self.conn, GET_REQ_BYTES, Flags::default(), key, HostLoad::default())?;
                self.pending_ops += 1;
                self.awaiting_reply += 1;
            }
        }
        self.gets_issued += 1;
        Ok(())
    }

    /// PUT a burst of `put_burst` values (one doorbell group one-sided;
    /// `put_burst` adaptive sends in RPC mode). An error before anything
    /// was posted propagates (the driver retries the round later); an
    /// error mid-burst just truncates the burst — the posted values are
    /// already in flight and the round completes with what it has.
    pub fn put(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        let mut posted = 0u32;
        for _ in 0..self.put_burst {
            let key = self.keys.sample(&mut self.rng);
            let len = self.layout.value_len(key);
            let res = match self.mode {
                KvMode::OneSided => {
                    let win = self.window.ok_or(RaasError::StaleWindow)?;
                    daemon.window_write(sim, win, len, self.layout.offset(key), key)
                }
                KvMode::Rpc => {
                    daemon.send(sim, self.conn, len, Flags::default(), key, HostLoad::default())
                }
            };
            match res {
                Ok(()) => {
                    self.pending_ops += 1;
                    self.puts_issued += 1;
                    posted += 1;
                }
                Err(e) if posted == 0 => return Err(e),
                Err(_) => break,
            }
        }
        if let (KvMode::OneSided, Some(win)) = (self.mode, self.window) {
            daemon.window_flush(sim, win)?;
        }
        Ok(())
    }

    /// Issue the next closed-loop round: GET with probability `read_pct`,
    /// else a PUT burst.
    pub fn issue(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        if self.rng.next_u64() % 100 < self.read_pct as u64 {
            self.get(sim, daemon)
        } else {
            self.put(sim, daemon)
        }
    }

    /// Account one delivery routed to this client. Returns `true` when
    /// the current round fully drained (the driver records latency and
    /// re-issues). Failed completions drain the round too, so closed
    /// loops keep moving under faults.
    pub fn on_delivery(&mut self, d: &Delivery) -> bool {
        match d {
            Delivery::OpComplete { .. } => {
                if self.pending_ops == 0 {
                    return false;
                }
                self.pending_ops -= 1;
            }
            Delivery::Message { .. } => {
                if self.awaiting_reply == 0 {
                    return false;
                }
                self.awaiting_reply -= 1;
            }
        }
        let done = self.pending_ops == 0 && self.awaiting_reply == 0;
        if done {
            self.ops_done += 1;
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;
    use crate::fabric::types::NodeId;
    use crate::raas::daemon::{connect_via, DaemonConfig};

    fn setup() -> (Sim, Vec<Daemon>) {
        let mut sim = Sim::new(FabricConfig::default());
        let daemons = (0..2)
            .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
            .collect();
        (sim, daemons)
    }

    fn quiesce(sim: &mut Sim, daemons: &mut [Daemon]) {
        for _ in 0..200_000 {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.step().is_none() {
                for d in daemons.iter_mut() {
                    d.pump(sim);
                }
                if sim.pending_events() == 0 {
                    return;
                }
            }
        }
        panic!("did not quiesce");
    }

    fn drain_client(sim: &mut Sim, daemon: &mut Daemon, client: &mut KvClient) -> u32 {
        let mut rounds = 0;
        while let Some(d) = daemon.recv_zero_copy(sim, client.app) {
            if client.on_delivery(&d) {
                rounds += 1;
            }
        }
        rounds
    }

    #[test]
    fn one_sided_get_put_round_trip() {
        let (mut sim, mut daemons) = setup();
        let layout = KvLayout { slots: 1024, slot_bytes: 1024 };
        let mut server = KvServer::new(&mut daemons[1], 6000, layout, KvMode::OneSided, 9);
        let capp = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 6000).unwrap();
        let mut client = KvClient::new(capp, conn, layout, 7, 0.99, KvMode::OneSided, 95, 4);
        client.register(&mut sim, &mut daemons[0]).unwrap();

        client.get(&mut sim, &mut daemons[0]).unwrap();
        quiesce(&mut sim, &mut daemons);
        assert_eq!(drain_client(&mut sim, &mut daemons[0], &mut client), 1);
        assert_eq!(client.ops_done, 1, "GET is one one-sided RTT");

        client.put(&mut sim, &mut daemons[0]).unwrap();
        quiesce(&mut sim, &mut daemons);
        assert_eq!(drain_client(&mut sim, &mut daemons[0], &mut client), 1);
        assert_eq!(client.ops_done, 2);
        assert_eq!(client.puts_issued, 4, "burst of put_burst WRITEs");
        // one doorbell group for the whole burst
        assert_eq!(daemons[0].stats.window_flushes, 1);
        assert_eq!(daemons[0].stats.writes_coalesced, 3);

        // the server CPU never saw any of it
        server.service(&mut sim, &mut daemons[1]);
        assert_eq!(server.puts_applied, 0, "one-sided PUTs bypass the server");
        assert_eq!(server.gets_served, 0);
        assert_eq!(daemons[1].stats.msgs_delivered, 0);
    }

    #[test]
    fn rpc_get_is_answered_and_put_is_applied() {
        let (mut sim, mut daemons) = setup();
        let layout = KvLayout { slots: 1024, slot_bytes: 1024 };
        let mut server = KvServer::new(&mut daemons[1], 6000, layout, KvMode::Rpc, 9);
        let capp = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 6000).unwrap();
        let mut client = KvClient::new(capp, conn, layout, 7, 0.99, KvMode::Rpc, 95, 2);
        client.register(&mut sim, &mut daemons[0]).unwrap(); // no-op in RPC mode

        client.get(&mut sim, &mut daemons[0]).unwrap();
        // drive: request over, server turn, reply back
        for _ in 0..200_000 {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            server.service(&mut sim, &mut daemons[1]);
            if sim.step().is_none() {
                for d in daemons.iter_mut() {
                    d.pump(&mut sim);
                }
                server.service(&mut sim, &mut daemons[1]);
                if sim.pending_events() == 0 {
                    break;
                }
            }
        }
        assert_eq!(server.gets_served, 1, "request answered");
        assert_eq!(drain_client(&mut sim, &mut daemons[0], &mut client), 1);
        assert_eq!(client.ops_done, 1, "send completion + reply message");

        client.put(&mut sim, &mut daemons[0]).unwrap();
        quiesce(&mut sim, &mut daemons);
        server.service(&mut sim, &mut daemons[1]);
        assert_eq!(server.puts_applied, 2, "both burst values applied");
        assert_eq!(drain_client(&mut sim, &mut daemons[0], &mut client), 1);
        assert_eq!(client.ops_done, 2);
    }

    #[test]
    fn layout_offsets_and_value_classes_in_bounds() {
        let l = KvLayout { slots: 64, slot_bytes: 128 << 10 };
        for k in 0..1000u64 {
            let off = l.offset(k);
            assert!(off + l.slot_bytes <= l.bytes());
            assert_eq!(off % l.slot_bytes, 0);
            let v = l.value_len(k);
            assert!(VALUE_CLASSES.contains(&v), "{v}");
            assert!(v > GET_REQ_BYTES && v <= l.slot_bytes);
        }
        // small slots cap the classes
        let small = KvLayout { slots: 64, slot_bytes: 1024 };
        for k in 0..100u64 {
            assert!(small.value_len(k) <= 1024);
        }
        // every class is actually drawn over a big enough key range
        let mut seen = std::collections::BTreeSet::new();
        for k in 0..10_000u64 {
            seen.insert(l.value_len(k));
        }
        assert_eq!(seen.len(), VALUE_CLASSES.len(), "all classes exercised");
    }
}
