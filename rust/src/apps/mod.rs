//! Example applications written against the RaaS API — the workloads the
//! paper's introduction motivates (key-value stores, RPC services, and the
//! model-serving application used by the end-to-end example).

pub mod kv;
pub mod rpc;
pub mod inference;
