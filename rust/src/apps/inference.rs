//! The live model-serving engine: RaaS shared-memory channels + the model
//! [`Executor`].
//!
//! This is the end-to-end example's core (real threads, wall-clock time):
//! client threads submit token payloads through RDMAvisor's lock-free
//! [`Channel`]s (the same structures the daemon uses on a real host), a
//! batcher thread collects requests into dynamic batches, executes the
//! transformer via [`Executor`] (simulated offline — see
//! [`crate::runtime`]), and pushes replies back through each client's
//! completion ring. Python never runs here.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::raas::shmem::{Channel, Descriptor};
use crate::runtime::Executor;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests per forward pass (≤ largest compiled variant batch).
    pub max_batch: usize,
    /// How long to wait for more requests before running a short batch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) }
    }
}

/// Serving statistics (wall clock).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Requests answered.
    pub requests: u64,
    /// Forward passes executed.
    pub batches: u64,
    /// Sum of batch sizes (for the mean).
    pub sum_batch: u64,
    /// Wall-clock nanoseconds spent inside the model executor.
    pub model_ns: u64,
}

impl ServeStats {
    /// Mean requests per executed batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.sum_batch as f64 / self.batches as f64
        }
    }
}

/// One in-flight request gathered from a client channel.
struct Gathered {
    client: usize,
    tag: u64,
    tokens: Vec<i32>,
}

/// The serving engine: client channels + stats. The [`Executor`] is NOT
/// stored here — it is created and owned entirely by the server thread
/// inside [`InferenceEngine::serve_loop`] (exactly the daemon-owns-the-NIC
/// discipline of the paper; on the PJRT deployment build the client is
/// additionally not `Send`, which forces the same structure).
pub struct InferenceEngine {
    /// One submit/complete channel pair per client.
    pub channels: Vec<Arc<Channel>>,
    artifacts_dir: String,
    seq_len: usize,
    /// Aggregate serving statistics (locked; read by the driver).
    pub stats: Mutex<ServeStats>,
    stop: AtomicBool,
}

impl InferenceEngine {
    /// Create the engine: one channel per client; sequence length comes
    /// from the artifact manifest (64 with the synthetic fallback).
    pub fn new(artifacts_dir: &str, n_clients: usize, ring_depth: usize) -> Arc<Self> {
        let seq_len = crate::runtime::Manifest::load(artifacts_dir)
            .ok()
            .and_then(|m| m.variants.first().map(|v| v.seq))
            .unwrap_or(64);
        let channels = (0..n_clients)
            .map(|_| Arc::new(Channel::new(ring_depth).expect("channel")))
            .collect();
        Arc::new(InferenceEngine {
            channels,
            artifacts_dir: artifacts_dir.to_string(),
            seq_len,
            stats: Mutex::new(ServeStats::default()),
            stop: AtomicBool::new(false),
        })
    }

    /// Sequence length requests are padded to.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Ask [`InferenceEngine::serve_loop`] to exit after its current batch.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Client-side submit: encode `tokens` seed into a descriptor. Payload
    /// transfer is modeled by the descriptor's `addr/len` (the tokens are
    /// derived deterministically from the tag on the server side, standing
    /// in for the registered-pool payload).
    pub fn submit(&self, client: usize, tag: u64) -> bool {
        let ch = &self.channels[client];
        let d = Descriptor::new(client as u32, 1, self.seq_len as u64, tag, tag);
        if ch.submit.push(d).is_ok() {
            ch.submit_bell.ring();
            true
        } else {
            false
        }
    }

    /// Client-side reap: pop completions; returns tags.
    pub fn reap(&self, client: usize) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(d) = self.channels[client].complete.pop() {
            out.push(d.user_tag);
        }
        out
    }

    fn tokens_for(&self, tag: u64) -> Vec<i32> {
        // deterministic payload derivation (stands in for pool bytes)
        (0..self.seq_len)
            .map(|i| (((tag.wrapping_mul(2654435761) as usize) + i * 7) % 256) as i32)
            .collect()
    }

    /// The batcher/worker loop: run on a dedicated thread. Loads and owns
    /// the PJRT executor locally (compile-once at thread start).
    pub fn serve_loop(self: &Arc<Self>) {
        let mut executor = Executor::load(&self.artifacts_dir)
            .expect("load artifacts (run `make artifacts` first)");
        let policy = BatchPolicy::default();
        let mut pending: Vec<Gathered> = Vec::new();
        let mut idle_spins = 0u32;
        while !self.stop.load(Ordering::SeqCst) {
            // gather from every client ring
            let mut got_any = false;
            for (ci, ch) in self.channels.iter().enumerate() {
                while pending.len() < policy.max_batch * 2 {
                    match ch.submit.pop() {
                        Some(d) => {
                            got_any = true;
                            pending.push(Gathered {
                                client: ci,
                                tag: d.user_tag,
                                tokens: self.tokens_for(d.user_tag),
                            });
                        }
                        None => break,
                    }
                }
            }
            if pending.is_empty() {
                idle_spins += 1;
                if idle_spins > 1000 {
                    // sleep on the first channel's doorbell (daemon idle path)
                    self.channels[0].submit_bell.wait_timeout(1);
                    idle_spins = 0;
                }
                continue;
            }
            // batch-or-wait
            if pending.len() < policy.max_batch && got_any {
                let t0 = Instant::now();
                while pending.len() < policy.max_batch && t0.elapsed() < policy.max_wait {
                    for (ci, ch) in self.channels.iter().enumerate() {
                        if let Some(d) = ch.submit.pop() {
                            pending.push(Gathered {
                                client: ci,
                                tag: d.user_tag,
                                tokens: self.tokens_for(d.user_tag),
                            });
                        }
                    }
                }
            }
            let take = pending.len().min(policy.max_batch);
            let batch: Vec<Gathered> = pending.drain(..take).collect();
            let rows: Vec<Vec<i32>> = batch.iter().map(|g| g.tokens.clone()).collect();

            let t0 = Instant::now();
            let result = executor.run_batched(&rows);
            let model_ns = t0.elapsed().as_nanos() as u64;

            let mut st = self.stats.lock().unwrap();
            st.batches += 1;
            st.sum_batch += batch.len() as u64;
            st.model_ns += model_ns;
            st.requests += batch.len() as u64;
            drop(st);

            match result {
                Ok((_, out)) => {
                    for (row, g) in batch.iter().enumerate() {
                        // reply: argmax of the last position (next token)
                        let next = out.argmax(row, self.seq_len - 1) as u64;
                        let ch = &self.channels[g.client];
                        let mut d = Descriptor::new(g.client as u32, 2, 8, next, g.tag);
                        d.status = 0;
                        while ch.complete.push(d).is_err() {
                            std::thread::yield_now();
                            d = Descriptor::new(g.client as u32, 2, 8, next, g.tag);
                        }
                        ch.complete_bell.ring();
                    }
                }
                Err(e) => {
                    for g in &batch {
                        let ch = &self.channels[g.client];
                        let mut d = Descriptor::new(g.client as u32, 2, 0, 0, g.tag);
                        d.status = 1;
                        let _ = ch.complete.push(d);
                        ch.complete_bell.ring();
                    }
                    eprintln!("inference error: {e:#}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_policy_defaults_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait < Duration::from_millis(10));
    }

    #[test]
    fn stats_mean_batch() {
        let mut s = ServeStats::default();
        s.batches = 4;
        s.sum_batch = 10;
        assert!((s.mean_batch() - 2.5).abs() < 1e-9);
    }

    // engine round-trip with the real executor is covered by
    // tests/integration_runtime.rs (needs artifacts/)
}
