//! A request/response RPC service over the RaaS API (FaSST-style [12]).
//!
//! Requests ride the adaptive `send` path; the server replies on the same
//! logical connection (the peer vQPN routes the response back). Used by
//! the quickstart example and as the traffic shape for the serving demo.

use crate::fabric::sim::Sim;
use crate::raas::api::{Flags, RaasError};
use crate::raas::daemon::{Daemon, Delivery};
use crate::raas::transport::HostLoad;
use crate::raas::vqpn::Vqpn;

/// Echo-style RPC server: replies `resp_bytes` to every request.
pub struct RpcServer {
    /// Server app session id on its daemon.
    pub app: u32,
    /// Reply payload size.
    pub resp_bytes: u64,
    /// Requests answered so far.
    pub served: u64,
    /// Accepted connections (server side of each logical conn).
    pub conns: Vec<Vqpn>,
    port: u16,
}

impl RpcServer {
    /// Register the server app and start listening on `port`.
    pub fn new(daemon: &mut Daemon, port: u16, resp_bytes: u64) -> RpcServer {
        let app = daemon.register_app();
        daemon.listen(app, port);
        RpcServer { app, resp_bytes, served: 0, conns: Vec::new(), port }
    }

    /// Accept new conns, serve pending requests (one reply per request).
    pub fn service(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        while let Some(c) = daemon.accept(self.app, self.port) {
            self.conns.push(c);
        }
        while let Some(d) = daemon.recv_zero_copy(sim, self.app) {
            if let Delivery::Message { conn, .. } = d {
                daemon.send(sim, conn, self.resp_bytes, Flags::default(), 0, HostLoad::default())?;
                self.served += 1;
            }
        }
        Ok(())
    }
}

/// RPC client: issues requests, counts responses.
pub struct RpcClient {
    /// Client app session id on its daemon.
    pub app: u32,
    /// Logical connection to the server.
    pub conn: Vqpn,
    /// Request payload size.
    pub req_bytes: u64,
    /// Requests issued so far.
    pub sent: u64,
    /// Responses received so far.
    pub responses: u64,
}

impl RpcClient {
    /// Create a client over an open connection.
    pub fn new(app: u32, conn: Vqpn, req_bytes: u64) -> RpcClient {
        RpcClient { app, conn, req_bytes, sent: 0, responses: 0 }
    }

    /// Issue one request on the adaptive send path.
    pub fn call(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> Result<(), RaasError> {
        daemon.send(sim, self.conn, self.req_bytes, Flags::default(), self.sent, HostLoad::default())?;
        self.sent += 1;
        Ok(())
    }

    /// Drain deliveries; responses are `Message`s from the server.
    pub fn drain(&mut self, sim: &mut Sim, daemon: &mut Daemon) -> u64 {
        let mut got = 0;
        while let Some(d) = daemon.recv(sim, self.app) {
            if matches!(d, Delivery::Message { .. }) {
                got += 1;
            }
        }
        self.responses += got;
        got
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::sim::FabricConfig;
    use crate::fabric::types::NodeId;
    use crate::raas::daemon::{connect_via, DaemonConfig};

    #[test]
    fn request_response_round_trip() {
        let mut sim = Sim::new(FabricConfig::default());
        let mut daemons: Vec<Daemon> = (0..2)
            .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
            .collect();
        let mut server = RpcServer::new(&mut daemons[1], 5000, 256);
        let capp = daemons[0].register_app();
        let conn = connect_via(&mut sim, &mut daemons, 0, capp, 1, 5000).unwrap();
        let mut client = RpcClient::new(capp, conn, 128);

        for _ in 0..8 {
            client.call(&mut sim, &mut daemons[0]).unwrap();
        }
        for _ in 0..400_000 {
            daemons[0].pump(&mut sim);
            server.service(&mut sim, &mut daemons[1]).unwrap();
            daemons[1].pump(&mut sim);
            if sim.step().is_none() {
                daemons[0].pump(&mut sim);
                server.service(&mut sim, &mut daemons[1]).unwrap();
                daemons[1].pump(&mut sim);
                if sim.pending_events() == 0 {
                    break;
                }
            }
        }
        client.drain(&mut sim, &mut daemons[0]);
        assert_eq!(server.served, 8);
        assert_eq!(client.responses, 8, "every request answered");
    }
}
