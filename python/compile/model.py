"""Layer-2 JAX model: the application payload compute served through RaaS.

A small encoder-style transformer that the end-to-end serving example runs
on every RPC payload: token ids -> embedding -> N blocks (LN -> fused Pallas
attention -> residual -> LN -> fused Pallas MLP -> residual) -> final LN ->
logits. Weights are generated deterministically from a seed and **baked into
the HLO as constants**, so the Rust runtime only feeds token ids — no weight
plumbing across the FFI boundary.

The same forward is available with the pure-jnp reference ops
(`use_kernels=False`) so pytest can assert the Pallas path matches.
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import mlp as mlp_k
from .kernels import ref


@dataclass(frozen=True)
class ModelConfig:
    """Serving-model hyperparameters. Defaults are the e2e example's size."""

    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    seq: int = 64
    dtype: str = "float32"
    block_q: int = 32  # pallas attention q-block
    block_m: int = 32  # pallas mlp row-block

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def variant_name(self, batch):
        return f"model_b{batch}"


# ~100M-param training-scale config used by examples/train_loop (L2-only,
# reference path; the serving artifacts use ModelConfig above).
BIG = ModelConfig(
    vocab=32000, d_model=768, n_heads=12, n_layers=12, d_ff=3072, seq=512
)


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic parameter pytree (scaled-normal init)."""
    key = jax.random.PRNGKey(seed)
    dtype = jnp.dtype(cfg.dtype)
    n_keys = 4 + cfg.n_layers * 10
    keys = iter(jax.random.split(key, n_keys))

    def norm(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    d, f, h = cfg.d_model, cfg.d_ff, cfg.n_heads
    params = {
        "embed": norm(next(keys), (cfg.vocab, d), 0.02),
        "pos": norm(next(keys), (cfg.seq, d), 0.02),
        "ln_f": {"gamma": jnp.ones((d,), dtype), "beta": jnp.zeros((d,), dtype)},
        "unembed": norm(next(keys), (d, cfg.vocab), d**-0.5),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "ln1": {"gamma": jnp.ones((d,), dtype), "beta": jnp.zeros((d,), dtype)},
            "wq": norm(next(keys), (d, d), d**-0.5),
            "wk": norm(next(keys), (d, d), d**-0.5),
            "wv": norm(next(keys), (d, d), d**-0.5),
            "wo": norm(next(keys), (d, d), d**-0.5),
            "ln2": {"gamma": jnp.ones((d,), dtype), "beta": jnp.zeros((d,), dtype)},
            "w1": norm(next(keys), (d, f), d**-0.5),
            "b1": jnp.zeros((f,), dtype),
            "w2": norm(next(keys), (f, d), f**-0.5),
            "b2": jnp.zeros((d,), dtype),
        }
        params["layers"].append(layer)
        for _ in range(4):  # consume the per-layer key budget deterministically
            next(keys)
    return params


def _split_heads(x, n_heads):
    seq, d = x.shape
    return x.reshape(seq, n_heads, d // n_heads).transpose(1, 0, 2)


def _merge_heads(x):
    h, seq, hd = x.shape
    return x.transpose(1, 0, 2).reshape(seq, h * hd)


def block_forward(x, layer, cfg: ModelConfig, use_kernels: bool):
    """One transformer block over x[seq, d_model]."""
    h = ref.layer_norm(x, layer["ln1"]["gamma"], layer["ln1"]["beta"])
    q = _split_heads(h @ layer["wq"], cfg.n_heads)
    k = _split_heads(h @ layer["wk"], cfg.n_heads)
    v = _split_heads(h @ layer["wv"], cfg.n_heads)
    if use_kernels:
        o = attn_k.attention(q, k, v, block_q=cfg.block_q)
    else:
        o = ref.attention(q, k, v)
    x = x + _merge_heads(o) @ layer["wo"]

    h = ref.layer_norm(x, layer["ln2"]["gamma"], layer["ln2"]["beta"])
    if use_kernels:
        m = mlp_k.mlp(h, layer["w1"], layer["b1"], layer["w2"], layer["b2"],
                      block_m=cfg.block_m)
    else:
        m = ref.mlp(h, layer["w1"], layer["b1"], layer["w2"], layer["b2"])
    return x + m


def forward_tokens(tokens, params, cfg: ModelConfig, use_kernels: bool = True):
    """Single-sequence forward: tokens[seq] int32 -> logits[seq, vocab]."""
    x = params["embed"][tokens] + params["pos"]
    for layer in params["layers"]:
        x = block_forward(x, layer, cfg, use_kernels)
    x = ref.layer_norm(x, params["ln_f"]["gamma"], params["ln_f"]["beta"])
    return x @ params["unembed"]


def batched_forward(tokens, params, cfg: ModelConfig, use_kernels: bool = True):
    """tokens[batch, seq] -> logits[batch, seq, vocab] (vmap over batch)."""
    fn = functools.partial(
        forward_tokens, params=params, cfg=cfg, use_kernels=use_kernels
    )
    return jax.vmap(fn)(tokens)


def serving_fn(cfg: ModelConfig, batch: int, seed: int = 0, use_kernels: bool = True):
    """Build the AOT-export function: params closed over (baked as consts).

    Returns (fn, example_args). fn(tokens[batch, seq] i32) ->
    (logits[batch, seq, vocab] f32,).
    """
    params = init_params(cfg, seed)

    def fn(tokens):
        return (batched_forward(tokens, params, cfg, use_kernels),)

    example = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    return fn, (example,)


def loss_fn(params, tokens, cfg: ModelConfig):
    """Next-token cross-entropy (reference path) — used by the training example."""
    logits = batched_forward(tokens, params, cfg, use_kernels=False)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def train_step(params, tokens, cfg: ModelConfig, lr: float = 3e-4):
    """One SGD step; returns (new_params, loss). Used by examples/train_loop."""
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss
