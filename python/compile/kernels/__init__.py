"""Layer-1 Pallas kernels for the RDMAvisor application payload compute.

These kernels implement the compute hot-spots of the model served *through*
the RaaS layer in the end-to-end serving example: a fused scaled-dot-product
attention kernel and a tiled two-layer MLP kernel.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the interpret path is both the correctness
oracle target and the artifact path. Real-TPU performance is *estimated* from
the BlockSpec schedule (see DESIGN.md §5 and EXPERIMENTS.md §Perf).
"""

from . import attention, mlp, ref  # noqa: F401
