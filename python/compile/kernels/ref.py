"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float assoc.)
reference here; pytest asserts allclose between the two across a hypothesis
sweep of shapes and dtypes. The references are also used by the L2 model
tests to validate the full forward pass.
"""

import jax.numpy as jnp


def softmax(x, axis=-1):
    """Numerically-stable softmax (explicit, so the oracle has no surprises)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention(q, k, v, scale=None):
    """Reference scaled-dot-product attention.

    q, k, v: [heads, seq, head_dim] (single sequence; batch is vmapped by
    the caller). Causal masking is NOT applied — the serving workload is
    full-context encoding of the request payload.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    probs = softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def causal_attention(q, k, v, scale=None):
    """Reference causal attention (used by the decode-style variant)."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    seq_q, seq_k = q.shape[-2], k.shape[-2]
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool), k=seq_k - seq_q)
    logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    probs = softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def gelu(x):
    """tanh-approx GELU, matching the kernel (keep both sides identical)."""
    c = jnp.asarray(0.7978845608028654, x.dtype)  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def mlp(x, w1, b1, w2, b2):
    """Reference 2-layer GELU MLP: x[seq, d] @ w1[d, f] -> gelu -> @ w2[f, d]."""
    h = x @ w1 + b1
    h = gelu(h)
    return h @ w2 + b2


def layer_norm(x, gamma, beta, eps=1e-5):
    """Reference LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def matmul(a, b):
    """Reference matmul for the tiled-matmul kernel."""
    return a @ b
