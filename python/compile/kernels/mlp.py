"""Tiled matmul + fused 2-layer GELU MLP as Pallas kernels.

The matmul kernel is the canonical MXU-shaped tiling: grid over
(M/block_m, N/block_n, K/block_k) with an f32 VMEM accumulator tile; the K
axis is the innermost (sequential) grid dimension so the accumulator tile is
revisited, matching the TPU's preferred stationary-output schedule.

The fused MLP kernel keeps the [block_m, d_ff] hidden activation tile in VMEM
between the two matmuls, avoiding an HBM round-trip for the activation —
this is the kernel-level fusion win the serving payload benefits from.

Lowered with ``interpret=True`` (see kernels/__init__.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _matmul_kernel(a_ref, b_ref, o_ref, *, nk):
    """Grid (i, j, k): accumulate a[i,k] @ b[k,j] into the revisited out tile.

    K is the innermost (sequential) grid axis, so o_ref maps to the same
    [block_m, block_n] tile for all k — the stationary-output schedule. The
    tile is zeroed at k==0 and accumulated in place (f32 output dtype).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(a, b, *, block_m=128, block_n=128, block_k=128, interpret=True):
    """Tiled a[M,K] @ b[K,N] with an f32 scratch accumulator in VMEM."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k})x({k},{n}) not divisible by blocks "
        f"({block_m},{block_n},{block_k})"
    )
    nk = k // block_k
    grid = (m // block_m, n // block_n, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One row-block program: full fused x@w1 -> gelu -> @w2 in VMEM."""
    x = x_ref[...]
    h = (
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...].astype(jnp.float32)
    ).astype(x.dtype)
    h = ref.gelu(h)
    o_ref[...] = (
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


def mlp(x, w1, b1, w2, b2, *, block_m=64, interpret=True):
    """Fused 2-layer GELU MLP over x[seq, d]; weights stay resident per block.

    Grid: (seq // block_m,). The [d, d_ff] / [d_ff, d] weight panels are
    re-streamed per row block; the hidden tile never touches HBM.
    """
    m, d = x.shape
    d_ff = w1.shape[1]
    if m % block_m != 0:
        block_m = m
    grid = (m // block_m,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def vmem_bytes(block_m, d, d_ff, dtype_bytes=4):
    """Static VMEM estimate for one fused-MLP program instance."""
    x_tile = block_m * d * dtype_bytes
    w = (d * d_ff + d_ff * d + d_ff + d) * dtype_bytes
    hidden = block_m * d_ff * 4
    out_tile = block_m * d * dtype_bytes
    return x_tile + w + hidden + out_tile
