"""Fused scaled-dot-product attention as a Pallas kernel.

TPU-minded design (see DESIGN.md §Hardware-Adaptation): the grid iterates
over (head, q-block); each program instance loads a [BLOCK_Q, head_dim] query
tile plus the full [seq_k, head_dim] K/V panels for its head into VMEM via
``BlockSpec``, computes logits on the MXU, applies a numerically-stable
softmax in f32, and writes the [BLOCK_Q, head_dim] output tile. For the
serving shapes used here (seq ≤ 256, head_dim ≤ 128) the K/V panels fit VMEM
comfortably (seq_k × head_dim × 4 B ≤ 128 KiB per operand), so no K-blocking /
online-softmax pass is needed; ``flash`` variants below add K-blocking with a
running max/denominator for longer sequences.

Everything is lowered with ``interpret=True`` — see kernels/__init__.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    """One (head, q-block) program instance: full-K fused attention."""
    q = q_ref[0]  # [block_q, d]
    k = k_ref[0]  # [seq_k, d]
    v = v_ref[0]  # [seq_k, d]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def attention(q, k, v, *, block_q=DEFAULT_BLOCK_Q, scale=None, interpret=True):
    """Fused attention over [heads, seq, head_dim] inputs.

    Grid: (heads, seq_q // block_q). K/V panels are indexed by head only, so
    the HBM->VMEM schedule re-streams K/V once per q-block (the classic
    non-flash schedule; fine while seq_k*d fits VMEM).
    """
    heads, seq_q, d = q.shape
    seq_k = k.shape[1]
    if seq_q % block_q != 0:
        block_q = seq_q  # fall back to one block per head
    if scale is None:
        scale = float(1.0 / (d**0.5))

    grid = (heads, seq_q // block_q)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, seq_k, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, seq_k, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq_q, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, seq_k):
    """Online-softmax (flash) variant: K/V streamed in block_k chunks.

    Keeps a running (max, denominator, accumulator) triple so VMEM holds only
    one K/V block at a time — the schedule the paper's GPU-era analogues
    express with thread-block staging of shared memory.
    """
    q = q_ref[0].astype(jnp.float32)  # [block_q, d]
    block_q, d = q.shape
    nblk = seq_k // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[0], i * block_k, block_k).astype(
            jnp.float32
        )
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[0], i * block_k, block_k).astype(
            jnp.float32
        )
        s = jnp.dot(q, k_blk.T) * scale  # [block_q, block_k]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v_blk)
        return m_new, l_new, acc

    m0 = jnp.full((block_q, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l_fin, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, acc0))
    o_ref[0] = (acc / l_fin).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, block_q=DEFAULT_BLOCK_Q, block_k=64, scale=None, interpret=True
):
    """Flash-style attention with K-blocking for sequences beyond VMEM."""
    heads, seq_q, d = q.shape
    seq_k = k.shape[1]
    if seq_q % block_q != 0:
        block_q = seq_q
    if seq_k % block_k != 0:
        block_k = seq_k
    if scale is None:
        scale = float(1.0 / (d**0.5))

    grid = (heads, seq_q // block_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_k=block_k, seq_k=seq_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, seq_k, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, seq_k, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq_q, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(heads, seq_q, seq_k, d, block_q=DEFAULT_BLOCK_Q, dtype_bytes=4):
    """Static VMEM footprint estimate for one program instance (fused path).

    Used by DESIGN.md/EXPERIMENTS.md §Perf to check the schedule against the
    ~16 MiB/core VMEM budget without TPU hardware.
    """
    block_q = min(block_q, seq_q)
    q_tile = block_q * d * dtype_bytes
    kv_panels = 2 * seq_k * d * dtype_bytes
    logits = block_q * seq_k * 4  # f32 accumulation
    out_tile = block_q * d * dtype_bytes
    return q_tile + kv_panels + logits + out_tile
