"""AOT export: lower the L2 serving model to HLO text for the Rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts

Emits one artifact per model variant (batch size) plus a ``manifest.json``
the Rust runtime reads to learn shapes/dtypes, and per-artifact flop/VMEM
estimates used by EXPERIMENTS.md §Perf.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention as attn_k
from .kernels import mlp as mlp_k

# Serving variants compiled ahead of time: one executable per batch size,
# selected at runtime by the RaaS inference app's dynamic batcher.
BATCH_VARIANTS = (1, 4, 8)
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flops_estimate(cfg: model.ModelConfig, batch: int) -> int:
    """Forward-pass MAC*2 estimate (matmuls only) for §Perf roofline math."""
    d, f, s, h = cfg.d_model, cfg.d_ff, cfg.seq, cfg.n_heads
    per_layer = (
        4 * s * d * d * 2          # q,k,v,o projections
        + 2 * h * s * s * cfg.head_dim * 2  # qk^T and pv
        + 2 * s * d * f * 2        # mlp
    )
    total = cfg.n_layers * per_layer + s * d * cfg.vocab * 2  # unembed
    return batch * total


def export_variant(cfg: model.ModelConfig, batch: int, out_dir: str) -> dict:
    """Lower one batch variant and write `<name>.hlo.txt`; return manifest row."""
    fn, example = model.serving_fn(cfg, batch, seed=SEED, use_kernels=True)
    lowered = jax.jit(fn).lower(*example)
    text = to_hlo_text(lowered)
    name = cfg.variant_name(batch)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "batch": batch,
        "seq": cfg.seq,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "d_ff": cfg.d_ff,
        "input": {"shape": [batch, cfg.seq], "dtype": "i32"},
        "output": {"shape": [batch, cfg.seq, cfg.vocab], "dtype": "f32"},
        "flops_fwd": flops_estimate(cfg, batch),
        "vmem_attn_bytes": attn_k.vmem_bytes(
            cfg.n_heads, cfg.seq, cfg.seq, cfg.head_dim, cfg.block_q
        ),
        "vmem_mlp_bytes": mlp_k.vmem_bytes(cfg.block_m, cfg.d_model, cfg.d_ff),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file mode: also write the b1 variant here")
    ap.add_argument("--batches", default=",".join(map(str, BATCH_VARIANTS)))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    cfg = model.ModelConfig()
    rows = []
    for b in [int(x) for x in args.batches.split(",") if x]:
        row = export_variant(cfg, b, args.out_dir)
        rows.append(row)
        print(f"wrote {row['file']} ({row['sha256'][:12]}, "
              f"{row['flops_fwd']/1e6:.1f} MFLOP/fwd)")

    manifest = {"seed": SEED, "dtype": cfg.dtype, "variants": rows}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote manifest.json with {len(rows)} variants")

    if args.out:
        import shutil
        shutil.copy(os.path.join(args.out_dir, rows[0]["file"]), args.out)


if __name__ == "__main__":
    main()
