"""L2 correctness: model forward (kernel path vs reference path), shapes,
determinism, and the training step used by the train-loop example."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64,
                        seq=16, block_q=8, block_m=8)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


def toks(key, batch, seq, vocab):
    return jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)


def test_forward_shape(params):
    t = toks(jax.random.PRNGKey(0), 2, CFG.seq, CFG.vocab)
    logits = model.batched_forward(t, params, CFG, use_kernels=False)
    assert logits.shape == (2, CFG.seq, CFG.vocab)


def test_kernel_path_matches_reference(params):
    """The Pallas-kernel forward must equal the pure-jnp forward."""
    t = toks(jax.random.PRNGKey(1), 2, CFG.seq, CFG.vocab)
    a = model.batched_forward(t, params, CFG, use_kernels=True)
    b = model.batched_forward(t, params, CFG, use_kernels=False)
    np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)


def test_forward_deterministic(params):
    t = toks(jax.random.PRNGKey(2), 1, CFG.seq, CFG.vocab)
    a = model.batched_forward(t, params, CFG)
    b = model.batched_forward(t, params, CFG)
    np.testing.assert_array_equal(a, b)


def test_init_deterministic():
    p1 = model.init_params(CFG, seed=7)
    p2 = model.init_params(CFG, seed=7)
    flat1 = jax.tree_util.tree_leaves(p1)
    flat2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(a, b)


def test_init_seed_changes_params():
    p1 = model.init_params(CFG, seed=0)
    p2 = model.init_params(CFG, seed=1)
    assert not np.allclose(p1["embed"], p2["embed"])


def test_batch_consistency(params):
    """Row i of a batched forward equals the single-sequence forward."""
    t = toks(jax.random.PRNGKey(3), 3, CFG.seq, CFG.vocab)
    batched = model.batched_forward(t, params, CFG, use_kernels=False)
    for i in range(3):
        single = model.forward_tokens(t[i], params, CFG, use_kernels=False)
        np.testing.assert_allclose(batched[i], single, rtol=1e-6, atol=1e-6)


def test_serving_fn_signature():
    fn, example = model.serving_fn(CFG, batch=4)
    assert example[0].shape == (4, CFG.seq)
    assert example[0].dtype == jnp.int32
    out = fn(toks(jax.random.PRNGKey(4), 4, CFG.seq, CFG.vocab))
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (4, CFG.seq, CFG.vocab)


def test_logits_finite(params):
    t = toks(jax.random.PRNGKey(5), 2, CFG.seq, CFG.vocab)
    logits = model.batched_forward(t, params, CFG)
    assert np.isfinite(np.asarray(logits)).all()


def test_layer_norm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 32)) * 5 + 3
    y = ref.layer_norm(x, jnp.ones((32,)), jnp.zeros((32,)))
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1, atol=1e-2)


def test_loss_decreases_under_sgd():
    """A few SGD steps on a fixed batch must reduce the loss (trainability)."""
    cfg = CFG
    params = model.init_params(cfg, seed=0)
    t = toks(jax.random.PRNGKey(8), 4, cfg.seq, cfg.vocab)
    step = jax.jit(lambda p: model.train_step(p, t, cfg, lr=1e-2))
    l0 = None
    p = params
    for i in range(5):
        p, loss = step(p)
        if l0 is None:
            l0 = float(loss)
    assert float(loss) < l0, f"loss did not decrease: {l0} -> {float(loss)}"


def test_loss_near_uniform_at_init():
    """Scaled init => initial loss ~ ln(vocab)."""
    p = model.init_params(CFG, seed=0)
    t = toks(jax.random.PRNGKey(9), 4, CFG.seq, CFG.vocab)
    loss = float(model.loss_fn(p, t, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 1.0
