"""AOT pipeline tests: HLO text emission, manifest integrity, stability."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

SMALL = model.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=1, d_ff=64,
                          seq=16, block_q=8, block_m=8)


def test_to_hlo_text_roundtrippable():
    fn, example = model.serving_fn(SMALL, batch=1)
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    assert text.startswith("HloModule"), text[:80]
    # parameters and root tuple present
    assert "parameter(0)" in text
    assert "ROOT" in text


def test_export_variant_writes_artifact(tmp_path):
    row = aot.export_variant(SMALL, 2, str(tmp_path))
    path = tmp_path / row["file"]
    assert path.exists() and path.stat().st_size > 1000
    assert row["input"]["shape"] == [2, SMALL.seq]
    assert row["output"]["shape"] == [2, SMALL.seq, SMALL.vocab]


def test_export_deterministic(tmp_path):
    r1 = aot.export_variant(SMALL, 1, str(tmp_path / "a".__str__()) if False else str(tmp_path))
    r2 = aot.export_variant(SMALL, 1, str(tmp_path))
    assert r1["sha256"] == r2["sha256"]


def test_flops_estimate_scales_with_batch():
    assert aot.flops_estimate(SMALL, 8) == 8 * aot.flops_estimate(SMALL, 1)


def test_manifest_contents(tmp_path):
    """End-to-end: run the CLI main on a tiny config via monkeypatched cfg."""
    rows = [aot.export_variant(SMALL, b, str(tmp_path)) for b in (1, 2)]
    manifest = {"seed": aot.SEED, "dtype": SMALL.dtype, "variants": rows}
    p = tmp_path / "manifest.json"
    p.write_text(json.dumps(manifest))
    loaded = json.loads(p.read_text())
    assert {v["name"] for v in loaded["variants"]} == {"model_b1", "model_b2"}
    for v in loaded["variants"]:
        assert (tmp_path / v["file"]).exists()


def test_hlo_has_no_custom_calls(tmp_path):
    """interpret=True must lower pallas to plain HLO (no Mosaic custom-call),
    otherwise the Rust CPU PJRT client cannot run the artifact."""
    row = aot.export_variant(SMALL, 1, str(tmp_path))
    text = (tmp_path / row["file"]).read_text()
    assert "custom-call" not in text or "mosaic" not in text.lower()
