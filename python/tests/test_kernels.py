"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes (as mandated); every case asserts allclose
against kernels/ref.py. interpret=True everywhere (CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # environment without hypothesis: parametrized fallback
    HAVE_HYPOTHESIS = False

from compile.kernels import attention as attn_k
from compile.kernels import mlp as mlp_k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


TOL = {"float32": dict(rtol=2e-5, atol=2e-5), "bfloat16": dict(rtol=5e-2, atol=5e-2)}


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("heads,seq,d", [(1, 16, 8), (2, 32, 16), (4, 64, 32)])
@pytest.mark.parametrize("block_q", [8, 16, 1000])
def test_attention_matches_ref(heads, seq, d, block_q):
    kq, kk, kv = keys(42, 3)
    q, k, v = rand(kq, (heads, seq, d)), rand(kk, (heads, seq, d)), rand(kv, (heads, seq, d))
    out = attn_k.attention(q, k, v, block_q=block_q)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, **TOL["float32"])


@pytest.mark.parametrize("heads,seq,d", [(2, 32, 16), (4, 64, 32)])
@pytest.mark.parametrize("block_k", [8, 16])
def test_flash_attention_matches_ref(heads, seq, d, block_k):
    kq, kk, kv = keys(7, 3)
    q, k, v = rand(kq, (heads, seq, d)), rand(kk, (heads, seq, d)), rand(kv, (heads, seq, d))
    out = attn_k.flash_attention(q, k, v, block_q=16, block_k=block_k)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, **TOL["float32"])


def test_flash_equals_fused():
    kq, kk, kv = keys(3, 3)
    q, k, v = (rand(k_, (2, 64, 16)) for k_ in (kq, kk, kv))
    a = attn_k.attention(q, k, v, block_q=32)
    b = attn_k.flash_attention(q, k, v, block_q=32, block_k=16)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_attention_kv_longer_than_q():
    """Cross-attention shape: seq_k != seq_q."""
    kq, kk, kv = keys(11, 3)
    q = rand(kq, (2, 16, 8))
    k = rand(kk, (2, 48, 8))
    v = rand(kv, (2, 48, 8))
    out = attn_k.attention(q, k, v, block_q=8)
    np.testing.assert_allclose(out, ref.attention(q, k, v), **TOL["float32"])


def test_attention_scale_override():
    kq, kk, kv = keys(12, 3)
    q, k, v = (rand(k_, (1, 16, 8)) for k_ in (kq, kk, kv))
    out = attn_k.attention(q, k, v, scale=0.25)
    np.testing.assert_allclose(out, ref.attention(q, k, v, scale=0.25), **TOL["float32"])


def test_attention_softmax_rows_bounded():
    """Output rows are convex combos of V rows -> within [min(V), max(V)]."""
    kq, kk, kv = keys(13, 3)
    q, k, v = (rand(k_, (2, 32, 8)) for k_ in (kq, kk, kv))
    out = np.asarray(attn_k.attention(q, k, v))
    assert out.max() <= np.asarray(v).max() + 1e-4
    assert out.min() >= np.asarray(v).min() - 1e-4


def test_attention_extreme_logits_stable():
    """Large-magnitude Q/K must not produce NaN (stable softmax)."""
    kq, kk, kv = keys(14, 3)
    q = rand(kq, (1, 16, 8), scale=50.0)
    k = rand(kk, (1, 16, 8), scale=50.0)
    v = rand(kv, (1, 16, 8))
    out = np.asarray(attn_k.attention(q, k, v))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, ref.attention(q, k, v), rtol=1e-4, atol=1e-4)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        heads=st.sampled_from([1, 2, 4]),
        seq_pow=st.integers(3, 6),
        d=st.sampled_from([8, 16, 32]),
        block_pow=st.integers(2, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_attention_hypothesis_sweep(heads, seq_pow, d, block_pow, seed):
        seq, block_q = 2**seq_pow, 2**block_pow
        kq, kk, kv = keys(seed, 3)
        q, k, v = (rand(k_, (heads, seq, d)) for k_ in (kq, kk, kv))
        out = attn_k.attention(q, k, v, block_q=block_q)
        np.testing.assert_allclose(out, ref.attention(q, k, v), **TOL["float32"])

    @settings(max_examples=15, deadline=None)
    @given(
        m_pow=st.integers(3, 6),
        d=st.sampled_from([8, 16, 32]),
        ff_mult=st.sampled_from([2, 4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_mlp_hypothesis_sweep(m_pow, d, ff_mult, seed):
        m, f = 2**m_pow, d * ff_mult
        ks = keys(seed, 5)
        x = rand(ks[0], (m, d))
        w1, b1 = rand(ks[1], (d, f)), rand(ks[2], (f,), scale=0.1)
        w2, b2 = rand(ks[3], (f, d)), rand(ks[4], (d,), scale=0.1)
        out = mlp_k.mlp(x, w1, b1, w2, b2, block_m=min(16, m))
        np.testing.assert_allclose(
            out, ref.mlp(x, w1, b1, w2, b2), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------- mlp/matmul

@pytest.mark.parametrize("m,k,n", [(16, 16, 16), (64, 32, 48), (128, 128, 128)])
def test_matmul_matches_ref(m, k, n):
    ka, kb = keys(5, 2)
    a, b = rand(ka, (m, k)), rand(kb, (k, n))
    out = mlp_k.matmul(a, b, block_m=16, block_n=16, block_k=16)
    np.testing.assert_allclose(out, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_single_block():
    ka, kb = keys(6, 2)
    a, b = rand(ka, (8, 8)), rand(kb, (8, 8))
    out = mlp_k.matmul(a, b, block_m=8, block_n=8, block_k=8)
    np.testing.assert_allclose(out, ref.matmul(a, b), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_nondivisible():
    ka, kb = keys(8, 2)
    a, b = rand(ka, (10, 8)), rand(kb, (8, 8))
    with pytest.raises(AssertionError):
        mlp_k.matmul(a, b, block_m=4, block_n=4, block_k=4)


@pytest.mark.parametrize("m,d,f,block_m", [(16, 8, 32, 8), (64, 32, 64, 16), (32, 16, 64, 1000)])
def test_mlp_matches_ref(m, d, f, block_m):
    ks = keys(9, 5)
    x = rand(ks[0], (m, d))
    w1, b1 = rand(ks[1], (d, f)), rand(ks[2], (f,), scale=0.1)
    w2, b2 = rand(ks[3], (f, d)), rand(ks[4], (d,), scale=0.1)
    out = mlp_k.mlp(x, w1, b1, w2, b2, block_m=block_m)
    np.testing.assert_allclose(out, ref.mlp(x, w1, b1, w2, b2), rtol=1e-4, atol=1e-4)


def test_gelu_matches_jax_nn():
    x = jnp.linspace(-4, 4, 101)
    np.testing.assert_allclose(ref.gelu(x), jax.nn.gelu(x, approximate=True),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- vmem budgets

def test_attention_vmem_within_budget():
    """The exported serving config's attention tile fits a 16 MiB VMEM."""
    from compile.model import ModelConfig
    cfg = ModelConfig()
    assert attn_k.vmem_bytes(cfg.n_heads, cfg.seq, cfg.seq, cfg.head_dim,
                             cfg.block_q) < 16 * 2**20


def test_mlp_vmem_within_budget():
    from compile.model import ModelConfig
    cfg = ModelConfig()
    assert mlp_k.vmem_bytes(cfg.block_m, cfg.d_model, cfg.d_ff) < 16 * 2**20
