//! END-TO-END driver: model serving through the full three-layer stack.
//!
//! Proves all layers compose on a real workload:
//!   L1/L2 — the Pallas-kernel transformer, AOT-compiled by
//!           `make artifacts` into `artifacts/*.hlo.txt`;
//!   runtime — Rust loads the HLO text and compiles it once on the PJRT
//!           CPU client (Python is NOT running);
//!   L3    — RDMAvisor's lock-free shared-memory channels carry request
//!           descriptors from real client threads to the daemon-side
//!           batcher, which forms dynamic batches and executes the model.
//!
//! Reports wall-clock latency percentiles, throughput, and batch shape —
//! the serving metrics a deployment would watch. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example inference_serving`

use std::sync::Arc;
use std::time::Instant;

use rdmavisor::apps::inference::InferenceEngine;
use rdmavisor::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let n_clients = args.usize_or("clients", 4);
    let per_client = args.u64_or("requests", 64);
    let window = args.usize_or("window", 4);
    let dir = args.str_or("artifacts", "artifacts");

    let engine = InferenceEngine::new(&dir, n_clients, 1024);
    println!(
        "engine up: {} client channels, seq_len {}",
        n_clients,
        engine.seq_len()
    );

    // daemon-side serving thread (owns the PJRT executor)
    let server = {
        let engine = engine.clone();
        std::thread::spawn(move || engine.serve_loop())
    };

    // warm-up request so PJRT compilation cost doesn't pollute latencies
    engine.submit(0, u64::MAX);
    let warm = Instant::now();
    loop {
        if engine.reap(0).iter().any(|&t| t == u64::MAX) {
            break;
        }
        if warm.elapsed().as_secs() > 120 {
            panic!("warmup timed out");
        }
        std::thread::yield_now();
    }
    println!("warmup done in {:.2?} (artifact compile + first batch)", warm.elapsed());

    // real client threads: closed loop with `window` outstanding each
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let engine: Arc<InferenceEngine> = engine.clone();
        handles.push(std::thread::spawn(move || {
            let mut lats = Vec::with_capacity(per_client as usize);
            let mut outstanding: Vec<(u64, Instant)> = Vec::new();
            let mut next = 0u64;
            let mut done = 0u64;
            while done < per_client {
                while outstanding.len() < window && next < per_client {
                    let tag = (c as u64) << 32 | next;
                    if engine.submit(c, tag) {
                        outstanding.push((tag, Instant::now()));
                        next += 1;
                    }
                }
                for tag in engine.reap(c) {
                    if let Some(pos) = outstanding.iter().position(|(t, _)| *t == tag) {
                        let (_, t) = outstanding.remove(pos);
                        lats.push(t.elapsed().as_micros() as u64);
                        done += 1;
                    }
                }
                std::thread::yield_now();
            }
            lats
        }));
    }

    let mut lats: Vec<u64> = Vec::new();
    for h in handles {
        lats.extend(h.join().unwrap());
    }
    let wall = t0.elapsed();
    engine.stop();
    // wake the server if it is blocked on a doorbell
    engine.channels[0].submit_bell.ring();
    let _ = server.join();

    lats.sort_unstable();
    let pct = |q: f64| lats[((lats.len() - 1) as f64 * q) as usize];
    let st = engine.stats.lock().unwrap();
    let total = lats.len() as u64;
    println!("\n== end-to-end serving results ==");
    println!("requests      : {total} across {n_clients} clients (window {window})");
    println!("wall time     : {wall:.2?}");
    println!("throughput    : {:.1} req/s", total as f64 / wall.as_secs_f64());
    println!(
        "latency       : p50 {} µs   p90 {} µs   p99 {} µs",
        pct(0.50),
        pct(0.90),
        pct(0.99)
    );
    println!(
        "batching      : {} batches, mean size {:.2}",
        st.batches,
        st.mean_batch()
    );
    println!(
        "model compute : {:.1} ms total ({:.2} ms per batch)",
        st.model_ns as f64 / 1e6,
        st.model_ns as f64 / 1e6 / st.batches.max(1) as f64
    );
    assert_eq!(total, per_client * n_clients as u64);
    println!("inference_serving OK");
}
