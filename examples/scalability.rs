//! Scalability walk-through: watch the Fig-5 mechanism happen.
//!
//! Runs the naive one-QP-per-connection stack and RDMAvisor side by side
//! at increasing connection counts and prints, for each: throughput, the
//! client NIC's ICM cache hit rate, QP count, and memory — making the
//! cause of the collapse (QP-context cache thrash) directly visible.
//!
//! Run: `cargo run --release --example scalability [--conns 100,400,800]`

use rdmavisor::fabric::time::Ns;
use rdmavisor::util::cli::Args;
use rdmavisor::workload::scenarios::{naive_random_read, raas_random_read, ScenarioCfg};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let conns = args.u64_list("conns", &[100, 400, 700, 1000]);

    println!(
        "{:>6} | {:>12} {:>11} {:>9} | {:>12} {:>11} {:>9}",
        "conns", "naive Gb/s", "cache hit", "QPs", "RaaS Gb/s", "cache hit", "QPs"
    );
    println!("{}", "-".repeat(84));
    for &c in &conns {
        let mut cfg = ScenarioCfg::default();
        cfg.conns = c as usize;
        cfg.duration = Ns::from_ms(40);
        cfg.warmup_frac = 0.4;
        let n = naive_random_read(&cfg);
        let r = raas_random_read(&cfg);
        println!(
            "{:>6} | {:>10.2}Gb {:>10.1}% {:>9} | {:>10.2}Gb {:>10.1}% {:>9}",
            c,
            n.gbps,
            n.cache_hit_rate * 100.0,
            c, // naive: one QP per connection
            r.gbps,
            r.cache_hit_rate * 100.0,
            3, // RaaS: one shared QP per remote node
        );
    }
    println!(
        "\nThe naive stack's QP count tracks connections; past the ~400-entry\n\
         NIC context cache its hit rate falls and throughput collapses.\n\
         RDMAvisor multiplexes every connection over 3 shared QPs (one per\n\
         remote machine), so the cache stays hot at any connection count."
    );
}
