//! Adaptive transport selection under load (§2.2).
//!
//! Demonstrates the daemon's CPU/memory-aware verb choice: the same
//! `send()` call flips between two-sided SEND, one-sided WRITE, and
//! (for explicit pulls) READ as message size and host load change —
//! "the user only needs to decide…, RaaS has mitigated the impact of
//! low-level details" (§1.3).
//!
//! Run: `cargo run --release --example adaptive_transport`

use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::types::NodeId;
use rdmavisor::raas::api::Flags;
use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig};
use rdmavisor::raas::transport::{HostLoad, Selector, SelectorConfig};

fn main() {
    // ---- policy table: what the selector decides across the size × load
    // space (pure policy, no fabric needed)
    println!("selector policy (transport always RC — UC has no SRQ):");
    println!(
        "{:>10} {:>12} {:>12} {:>14}",
        "size", "both idle", "local busy", "user RC|WRITE"
    );
    for &size in &[256u64, 1 << 10, 4 << 10, 64 << 10, 1 << 20] {
        let idle = HostLoad { cpu: 0.1, mem: 0.1 };
        let busy = HostLoad { cpu: 0.9, mem: 0.3 };
        let mut s1 = Selector::new(SelectorConfig::default());
        let mut s2 = Selector::new(SelectorConfig::default());
        let mut s3 = Selector::new(SelectorConfig::default());
        let a = s1.choose(size, Flags::default(), idle, idle, 4096).unwrap();
        let b = s2.choose(size, Flags::default(), busy, idle, 4096).unwrap();
        let c = s3
            .choose(size, Flags::RC | Flags::WRITE, idle, idle, 4096)
            .unwrap();
        println!(
            "{:>10} {:>12} {:>12} {:>14}",
            rdmavisor::figures::human_size(size),
            a.verb.to_string(),
            b.verb.to_string(),
            c.verb.to_string()
        );
    }

    // ---- live: drive the daemon and watch its decision counters move
    let mut sim = Sim::new(FabricConfig::default());
    let mut daemons = vec![
        Daemon::start(&mut sim, NodeId(0), DaemonConfig::default()),
        Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
    ];
    let sapp = daemons[1].register_app();
    daemons[1].listen(sapp, 1);
    let app = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, app, 1, 1).unwrap();

    // mixed workload: 70% small RPCs, 30% bulk transfers
    for i in 0..100u64 {
        let len = if i % 10 < 7 { 512 } else { 256 << 10 };
        daemons[0]
            .send(&mut sim, conn, len, Flags::default(), i, HostLoad::default())
            .unwrap();
    }
    for _ in 0..2_000_000 {
        for d in daemons.iter_mut() {
            d.pump(&mut sim);
        }
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            if sim.pending_events() == 0 {
                break;
            }
        }
    }
    let sel = &daemons[0].selector;
    println!("\nmixed workload (100 sends, 70% small / 30% bulk):");
    println!(
        "  daemon chose SEND {}x, WRITE {}x (staging: {} memcpy, {} memreg)",
        sel.chose_send,
        sel.chose_write,
        daemons[0].stats.send_staged_memcpy,
        daemons[0].stats.send_staged_memreg
    );
    assert!(sel.chose_send >= 60 && sel.chose_write >= 20);
    println!("adaptive_transport OK");
}
