//! Quickstart: the RaaS socket-like API in ~60 lines.
//!
//! Stands up a 2-node simulated cluster with an RDMAvisor daemon on each,
//! connects like a socket program (listen/connect/accept — Fig 3), then:
//!  1. sends a small message (daemon adaptively picks two-sided SEND),
//!  2. sends a large message (daemon picks one-sided WRITE-with-imm),
//!  3. pins `RC|READ` via FLAGS for a one-sided pull, knowledgeable-user style.
//!
//! Run: `cargo run --release --example quickstart`

use rdmavisor::fabric::sim::{FabricConfig, Sim};
use rdmavisor::fabric::types::NodeId;
use rdmavisor::raas::api::Flags;
use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig, Delivery};
use rdmavisor::raas::transport::HostLoad;

fn pump_until_quiet(sim: &mut Sim, daemons: &mut [Daemon]) {
    for _ in 0..1_000_000 {
        for d in daemons.iter_mut() {
            d.pump(sim);
        }
        if sim.step().is_none() {
            for d in daemons.iter_mut() {
                d.pump(sim);
            }
            if sim.pending_events() == 0 {
                return;
            }
        }
    }
    panic!("cluster did not quiesce");
}

fn main() {
    // a 2-node cluster: every machine runs one RDMAvisor daemon
    let mut sim = Sim::new(FabricConfig::default());
    let mut daemons = vec![
        Daemon::start(&mut sim, NodeId(0), DaemonConfig::default()),
        Daemon::start(&mut sim, NodeId(1), DaemonConfig::default()),
    ];

    // server side: register an app and listen on port 7000
    let server_app = daemons[1].register_app();
    daemons[1].listen(server_app, 7000);

    // client side: connect — this transparently creates (or reuses!) the
    // one shared RC QP between the two machines and allocates a vQPN
    let client_app = daemons[0].register_app();
    let conn = connect_via(&mut sim, &mut daemons, 0, client_app, 1, 7000).unwrap();
    let server_conn = daemons[1].accept(server_app, 7000).unwrap();
    println!("connected: client vQPN {:?} <-> server vQPN {:?}", conn, server_conn);
    println!("shared QPs on client node: {}", daemons[0].shared_qp_count());

    // 1. small message: the daemon picks two-sided SEND
    let verb = daemons[0]
        .send(&mut sim, conn, 512, Flags::default(), 1, HostLoad::default())
        .unwrap();
    println!("send(512 B)   -> daemon chose {verb}");

    // 2. large message: the daemon picks one-sided WRITE
    let verb = daemons[0]
        .send(&mut sim, conn, 256 << 10, Flags::default(), 2, HostLoad::default())
        .unwrap();
    println!("send(256 KB)  -> daemon chose {verb}");

    // 3. knowledgeable user: pin RC|READ to pull 64 KB from the peer pool
    daemons[0].read(&mut sim, conn, 64 << 10, 0, 3).unwrap();
    println!("read(64 KB)   -> pinned RC READ");

    pump_until_quiet(&mut sim, &mut daemons);

    // server receives the two messages (zero-copy delivery)
    let mut got = Vec::new();
    while let Some(d) = daemons[1].recv_zero_copy(&mut sim, server_app) {
        if let Delivery::Message { len, .. } = d {
            got.push(len);
        }
    }
    println!("server received messages: {got:?}");

    // client sees completions for all three ops
    let mut completions = 0;
    while let Some(d) = daemons[0].recv(&mut sim, client_app) {
        if matches!(d, Delivery::OpComplete { ok: true, .. }) {
            completions += 1;
        }
    }
    println!("client completions: {completions}");
    println!(
        "virtual time elapsed: {}  (daemon stats: {:?} WRs in {} batches)",
        sim.now(),
        daemons[0].stats.wrs_posted,
        daemons[0].stats.batches_posted
    );
    assert_eq!(got.len(), 2);
    assert_eq!(completions, 3);
    println!("quickstart OK");
}
