//! KV-store demo: a HERD-style key-value service on RaaS.
//!
//! One server node holds a 64 Mslot value table in its daemon pool; three
//! client nodes run zipf-skewed GET (one-sided READ, zero server CPU) and
//! PUT (adaptive send) workloads. Reports per-client throughput, GET
//! latency percentiles, and the server's CPU ledger — demonstrating the
//! paper's point that one-sided GETs leave the server cores idle.
//!
//! Run: `cargo run --release --example kv_store [--gets N] [--put-ratio PCT]`

use rdmavisor::apps::kv::{KvClient, KvLayout, KvServer};
use rdmavisor::fabric::sim::{FabricConfig, Notification, Sim};
use rdmavisor::fabric::time::Ns;
use rdmavisor::fabric::types::NodeId;
use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig};
use rdmavisor::util::cli::Args;
use rdmavisor::util::stats::Histogram;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let target_gets: u64 = args.u64_or("gets", 2000);
    let put_pct: u64 = args.u64_or("put-ratio", 5);

    let mut sim = Sim::new(FabricConfig::default());
    let mut daemons: Vec<Daemon> = (0..4)
        .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
        .collect();

    let layout = KvLayout { slots: 65_536, slot_bytes: 1024 };
    let mut server = KvServer::new(&mut daemons[0], 6000, layout);

    // three client machines, 8 connections each
    let mut clients = Vec::new();
    for node in 1..4usize {
        for c in 0..8u64 {
            let app = daemons[node].register_app();
            let conn = connect_via(&mut sim, &mut daemons, node, app, 0, 6000).unwrap();
            clients.push((node, KvClient::new(app, conn, layout, node as u64 * 100 + c, 0.99)));
        }
    }
    println!("cluster up: {} clients over {} shared QPs at the server",
        clients.len(), daemons[0].shared_qp_count());

    // closed loop: every client keeps 4 ops outstanding
    let mut issued = 0u64;
    for (node, client) in clients.iter_mut() {
        for _ in 0..4 {
            if issued % 100 < put_pct {
                client.put(&mut sim, &mut daemons[*node], 1024).unwrap();
            } else {
                client.get(&mut sim, &mut daemons[*node]).unwrap();
            }
            issued += 1;
        }
    }

    let mut lat = Histogram::new();
    let mut done = 0u64;
    let mut last_issue: Vec<Ns> = vec![sim.now(); clients.len()];
    while done < target_gets {
        let Some(notes) = sim.step() else { break };
        let mut touched = false;
        for n in &notes {
            if matches!(n, Notification::CqeReady { .. }) {
                touched = true;
            }
        }
        if touched {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            server.service(&mut sim, &mut daemons[0]);
            for (i, (node, client)) in clients.iter_mut().enumerate() {
                let completed = client.drain(&mut sim, &mut daemons[*node]);
                for _ in 0..completed {
                    lat.record(sim.now().saturating_sub(last_issue[i]).0);
                    done += 1;
                    if issued % 100 < put_pct {
                        client.put(&mut sim, &mut daemons[*node], 1024).unwrap();
                    } else {
                        client.get(&mut sim, &mut daemons[*node]).unwrap();
                    }
                    issued += 1;
                    last_issue[i] = sim.now();
                }
            }
        }
    }

    let elapsed = sim.now();
    let server_cpu = daemons[0].snapshot(&sim).cpu_cores;
    println!("\n== results ==");
    println!("ops completed : {done} ({put_pct}% puts) in {elapsed}");
    println!(
        "throughput    : {:.2} Mops/s",
        done as f64 * 1e3 / elapsed.0.max(1) as f64
    );
    println!(
        "GET latency   : p50 {:.1} µs   p99 {:.1} µs",
        lat.p50() as f64 / 1e3,
        lat.p99() as f64 / 1e3
    );
    println!(
        "server CPU    : {:.2} cores-equivalent (one-sided GETs bypass the CPU)",
        server_cpu
    );
    println!("server PUTs   : {} applied", server.puts_applied);
    assert!(done >= target_gets);
    println!("kv_store OK");
}
