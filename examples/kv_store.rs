//! KV-store demo: a HERD-style key-value service on RaaS.
//!
//! One server node holds a 64 MB value table in its daemon pool; three
//! client nodes run zipf-skewed GET/PUT rounds against it. Each client
//! registers a remote window once, then GETs are single one-sided READ
//! RTTs (zero server CPU — the Storm repeat-get pattern) and PUT bursts
//! coalesce into one doorbell group (RDMAbox request merging). Reports
//! per-client throughput, round latency percentiles, and the server's
//! CPU ledger — demonstrating the paper's point that one-sided ops leave
//! the server cores idle. `--rpc` flips every client to the SEND-RPC
//! baseline for comparison.
//!
//! Run: `cargo run --release --example kv_store [--rounds N] [--put-ratio PCT] [--rpc]`

use rdmavisor::apps::kv::{KvClient, KvLayout, KvMode, KvServer};
use rdmavisor::fabric::sim::{FabricConfig, Notification, Sim};
use rdmavisor::fabric::time::Ns;
use rdmavisor::fabric::types::NodeId;
use rdmavisor::raas::daemon::{connect_via, Daemon, DaemonConfig};
use rdmavisor::util::cli::Args;
use rdmavisor::util::stats::Histogram;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let target_rounds: u64 = args.u64_or("rounds", 2000);
    let put_pct: u64 = args.u64_or("put-ratio", 5).min(100);
    let mode = if args.flag("rpc") { KvMode::Rpc } else { KvMode::OneSided };

    let mut sim = Sim::new(FabricConfig::default());
    let mut daemons: Vec<Daemon> = (0..4)
        .map(|i| Daemon::start(&mut sim, NodeId(i), DaemonConfig::default()))
        .collect();

    let layout = KvLayout { slots: 65_536, slot_bytes: 1024 };
    let mut server = KvServer::new(&mut daemons[0], 6000, layout, mode, 1);

    // three client machines, 8 closed-loop clients each
    let mut clients = Vec::new();
    for node in 1..4usize {
        for c in 0..8u64 {
            let app = daemons[node].register_app();
            let conn = connect_via(&mut sim, &mut daemons, node, app, 0, 6000).unwrap();
            let seed = node as u64 * 100 + c;
            let mut client =
                KvClient::new(app, conn, layout, seed, 0.99, mode, (100 - put_pct) as u32, 4);
            client.register(&mut sim, &mut daemons[node]).expect("register window");
            clients.push((node, client));
        }
    }
    println!(
        "cluster up: {} clients over {} shared QPs at the server ({} mode)",
        clients.len(),
        daemons[0].shared_qp_count(),
        if mode == KvMode::Rpc { "SEND-RPC" } else { "one-sided" }
    );

    // closed loop: every client keeps one GET/PUT round in flight
    for (node, client) in clients.iter_mut() {
        client.issue(&mut sim, &mut daemons[*node]).expect("issue");
    }
    for node in 1..4usize {
        daemons[node].pump(&mut sim);
    }

    let mut lat = Histogram::new();
    let mut done = 0u64;
    let mut last_issue: Vec<Ns> = vec![sim.now(); clients.len()];
    while done < target_rounds {
        let Some(notes) = sim.step() else { break };
        let mut touched = false;
        for n in &notes {
            if matches!(n, Notification::CqeReady { .. }) {
                touched = true;
            }
        }
        if touched {
            for d in daemons.iter_mut() {
                d.pump(&mut sim);
            }
            server.service(&mut sim, &mut daemons[0]);
            daemons[0].pump(&mut sim); // flush any RPC replies now
            for (i, (node, client)) in clients.iter_mut().enumerate() {
                let mut rounds = 0u32;
                while let Some(d) = daemons[*node].recv_zero_copy(&mut sim, client.app) {
                    if client.on_delivery(&d) {
                        rounds += 1;
                    }
                }
                for _ in 0..rounds {
                    lat.record(sim.now().saturating_sub(last_issue[i]).0);
                    done += 1;
                    last_issue[i] = sim.now();
                    client.issue(&mut sim, &mut daemons[*node]).expect("issue");
                }
                if rounds > 0 {
                    daemons[*node].pump(&mut sim);
                }
            }
        }
    }

    let elapsed = sim.now();
    let server_cpu = daemons[0].snapshot(&sim).cpu_cores;
    println!("\n== results ==");
    println!("rounds done   : {done} ({put_pct}% put rounds) in {elapsed}");
    println!("throughput    : {:.2} Mops/s", done as f64 * 1e3 / elapsed.0.max(1) as f64);
    println!(
        "round latency : p50 {:.1} µs   p99 {:.1} µs",
        lat.p50() as f64 / 1e3,
        lat.p99() as f64 / 1e3
    );
    println!(
        "server CPU    : {:.2} cores-equivalent (one-sided ops bypass the CPU)",
        server_cpu
    );
    println!(
        "server PUTs   : {} applied (0 = one-sided writes landed directly)",
        server.puts_applied
    );
    let totals: (u64, u64) =
        clients.iter().fold((0, 0), |(g, p), (_, c)| (g + c.gets_issued, p + c.puts_issued));
    println!("client issue  : {} GETs, {} PUT values", totals.0, totals.1);
    for node in 1..4usize {
        let s = &daemons[node].stats;
        if s.window_flushes > 0 {
            println!(
                "node {node} doorbell: {} flushes, {} writes coalesced",
                s.window_flushes, s.writes_coalesced
            );
        }
    }
    assert!(done >= target_rounds);
    println!("kv_store OK");
}
